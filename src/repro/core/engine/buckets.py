"""Bucket stage: AOT-warmable compile shapes for serving (docs/serving.md).

Length buckets make mid-stream admission and extreme-rag fleets cheap:
init blocks and segment packs are padded up to a small power-of-two table
of shapes, so every bucket is pre-compilable (``warm_bucket_solvers``) and
a node joining live pays device math, never a trace.  Zero-pad rows add
exactly zero to gram/rhs sums and fully-masked pad steps freeze the
filter, so bucketed results stay pinned to the monolithic pack
(tests/test_slot_serving.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine.estimate import _node_init_gram
from repro.core.engine.packing import pack_fleet_inputs
from repro.core.engine.segment import run_fleet
from repro.core.engine.types import Array, EngineConfig, FleetInputs

#: Default length-bucket table, shared by the init solves (window counts)
#: and the segment packs (step counts).  Powers of two: each bucket at most
#: doubles the padded work, and the whole table is cheap to pre-compile.
DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256, 512)


def bucket_for(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket that fits a length-``n`` block.

    Lengths beyond the table round up to the next power of two, so the
    mapping is total — an oversized node costs one extra compile instead of
    an error.  ``n`` must be positive (a zero-length block has no bucket).
    """
    if n <= 0:
        raise ValueError(f"bucket_for needs a positive length, got {n}")
    for b in sorted(buckets):
        if n <= b:
            return int(b)
    return 1 << (int(n) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("config",))
def _bucket_init_solve(c_pad: Array, w_pad: Array, config: EngineConfig) -> Array:
    """Single-node gram-domain NNLS over a bucket-padded init block.

    One trace per (bucket length, M, config) — the compile unit the slot
    pool pre-warms.  Zero-padding is *exact* here: the gram/rhs are sums
    over window rows and a zero row adds exactly zero to both."""
    from repro.core.disaggregation import solve_nnls_gram

    gram, rhs = _node_init_gram(c_pad, w_pad)
    eye = config.init_lam * jnp.eye(c_pad.shape[-1], dtype=c_pad.dtype)
    return solve_nnls_gram(gram + eye, rhs, iters=config.init_iters)


def bucketed_initial_estimate(
    c: Array,
    w: Array,
    config: EngineConfig = EngineConfig(),
    *,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
) -> Array:
    """(M,) X_0 for ONE node via a length-bucketed compile (§4.2, serving).

    The serving-path twin of ``fleet_initial_estimate``: a node admitted
    mid-stream brings an init block of arbitrary length ``n``, which would
    force a fresh trace per length.  Instead the block is zero-padded to
    ``bucket_for(n)`` windows and solved by the per-bucket jitted
    ``_bucket_init_solve`` — after ``warm_bucket_solvers`` every admission
    lands in a pre-warmed compile.  Padding with zero rows changes the
    gram/rhs by exactly zero, so the estimate matches the unpadded solve up
    to float reassociation of the row reduction.
    """
    import numpy as np

    c = np.asarray(c, np.float32)
    w = np.asarray(w, np.float32)
    n, m = c.shape
    bkt = bucket_for(n, buckets)
    if bkt > n:
        c = np.concatenate([c, np.zeros((bkt - n, m), np.float32)])
        w = np.concatenate([w, np.zeros((bkt - n,), np.float32)])
    return _bucket_init_solve(jnp.asarray(c), jnp.asarray(w), config)


def warm_bucket_solvers(
    num_fns: int,
    config: EngineConfig = EngineConfig(),
    *,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
) -> int:
    """Pre-compile the bucketed init solve for every bucket in the table.

    Called by ``SlotFleetSession.warmup`` so a node joining mid-stream pays
    device math, never a trace.  Returns the number of solvers warmed."""
    for n in buckets:
        _bucket_init_solve(
            jnp.zeros((n, num_fns), jnp.float32), jnp.zeros((n,), jnp.float32), config
        ).block_until_ready()
    return len(buckets)


class FleetBucket(NamedTuple):
    """One length bucket of a bucketed fleet pack (``pack_fleet_buckets``).

    ``inputs`` is a normal (len(nodes), steps, n_w, ...) ``FleetInputs``
    block padded to the bucket's step count — ``steps`` is the compile
    shape, shared by every fleet whose nodes land in this bucket."""

    inputs: FleetInputs
    nodes: tuple          # original fleet indices packed into this bucket
    lengths: tuple        # their real per-node window counts
    steps: int            # bucket step count (the compile shape)


def pad_waste_frac(
    lengths, step_windows: int, *, s: int | None = None
) -> float:
    """Fraction of engine ticks that are padding in a single (B, s, n_w) pack.

    ``pack_fleet_inputs`` pads every node to ``s = max_i S_i`` steps; on an
    extreme-rag fleet (one long node, many short ones) most ticks are
    masked padding.  This is the waste metric the bucketed pack reclaims —
    compare against ``bucketed_pad_waste``.  ``s`` overrides the pack's
    step count (defaults to ``max_i S_i``)."""
    import numpy as np

    lens = np.asarray(lengths, np.int64)
    s_nodes = lens // step_windows
    s = int(s_nodes.max()) if s is None else int(s)
    if s == 0:
        raise ValueError("no node has a full step; nothing to pack")
    real = int(np.minimum(s_nodes, s).sum()) * step_windows
    return float(1.0 - real / (s * step_windows * len(lens)))


def bucketed_pad_waste(buckets: "list[FleetBucket]", step_windows: int) -> float:
    """Overall padding fraction across a bucketed pack's groups.

    Same numerator as ``pad_waste_frac`` (each node's real full-step
    ticks); the denominator is the sum of the per-bucket padded shapes,
    which is what the engines actually compute over."""
    import numpy as np

    real = total = 0
    for bk in buckets:
        s_nodes = np.minimum(np.asarray(bk.lengths, np.int64) // step_windows, bk.steps)
        real += int(s_nodes.sum()) * step_windows
        total += len(bk.nodes) * bk.steps * step_windows
    return float(1.0 - real / total)


def _pad_steps(inputs: FleetInputs, s_to: int) -> FleetInputs:
    """Pad a packed block to ``s_to`` steps with fully-masked zero steps."""
    b, s, n_w, m = inputs.c.shape
    if s >= s_to:
        return inputs
    d = s_to - s
    zf = functools.partial(jnp.zeros, dtype=jnp.float32)
    mask = (
        inputs.mask if inputs.mask is not None else jnp.ones((b, s, n_w), jnp.float32)
    )
    return FleetInputs(
        c=jnp.concatenate([inputs.c, zf((b, d, n_w, m))], axis=1),
        w=jnp.concatenate([inputs.w, zf((b, d, n_w))], axis=1),
        a=jnp.concatenate([inputs.a, zf((b, d, m))], axis=1),
        lat_sum=jnp.concatenate([inputs.lat_sum, zf((b, d, m))], axis=1),
        lat_sumsq=jnp.concatenate([inputs.lat_sumsq, zf((b, d, m))], axis=1),
        mask=jnp.concatenate([mask, zf((b, d, n_w))], axis=1),
        fn_mask=inputs.fn_mask,
    )


def pack_fleet_buckets(
    c_windows: Array,
    w_windows: Array,
    a_windows: Array,
    lat_sum_w: Array,
    lat_sumsq_w: Array,
    *,
    step_windows: int,
    lengths,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
) -> "list[FleetBucket]":
    """Length-bucketed fleet packing: reclaim ``pad_waste_frac`` on extreme rag.

    The single-block ``pack_fleet_inputs`` pads every node to the longest
    node's step count — on a fleet of mostly-short nodes plus one long one,
    almost every engine tick is masked padding.  Here nodes are grouped by
    ``bucket_for`` of their full-step count and each group packs to its
    *bucket's* step count (padded up with fully-masked steps so the block
    shape is exactly the bucket — the compile shape stays stable across
    fleets, which is what makes the buckets pre-warmable).  Within a group
    the existing mask machinery applies unchanged, so results are pinned
    per node against the monolithic pack (tests/test_slot_serving.py).

    Returns one ``FleetBucket`` per occupied bucket, ascending by step
    count; run them with ``run_fleet_bucketed``.
    """
    import numpy as np

    arrs = [np.asarray(x) for x in (c_windows, w_windows, a_windows, lat_sum_w, lat_sumsq_w)]
    b = arrs[0].shape[0]
    lens = np.asarray(lengths, np.int64)
    if lens.shape != (b,):
        raise ValueError(f"lengths must have shape ({b},), got {lens.shape}")
    s_nodes = lens // step_windows
    if int(s_nodes.max()) == 0:
        raise ValueError(
            f"need at least step_windows={step_windows} windows on at "
            f"least one node, got lengths {lens.tolist()}"
        )
    groups: dict[int, list[int]] = {}
    for i, s_i in enumerate(s_nodes):
        groups.setdefault(bucket_for(max(int(s_i), 1), buckets), []).append(i)

    out = []
    for bkt_s in sorted(groups):
        idx = groups[bkt_s]
        need = bkt_s * step_windows

        def take(arr):
            sub = arr[idx]
            if sub.shape[1] < need:
                pad = np.zeros(
                    (len(idx), need - sub.shape[1]) + sub.shape[2:], sub.dtype
                )
                sub = np.concatenate([sub, pad], axis=1)
            return jnp.asarray(sub[:, :need], jnp.float32)

        # A node's sub-step tail feeds no update; clamp its length to the
        # bucket span so the group block never needs the tail windows.
        grp_lens = [min(int(lens[i]), need) for i in idx]
        packed = pack_fleet_inputs(
            *[take(a) for a in arrs], step_windows=step_windows, lengths=grp_lens
        )
        out.append(
            FleetBucket(
                inputs=_pad_steps(packed, bkt_s),
                nodes=tuple(idx),
                lengths=tuple(int(lens[i]) for i in idx),
                steps=bkt_s,
            )
        )
    return out


def run_fleet_bucketed(
    buckets: "list[FleetBucket]",
    config: EngineConfig = EngineConfig(),
    *,
    engine=None,
    with_ticks: bool = False,
):
    """Run every bucket of a bucketed pack and stitch estimates to fleet order.

    ``engine`` is any segment engine (``run_fleet`` default,
    ``run_fleet_gram``, ``run_fleet_stream``).  Per-node math is
    node-independent, so scattering each group's rows back by its original
    indices reproduces the monolithic pack's estimates (up to vmap
    batch-size reassociation; pinned at 1e-5).  Trajectories keep their
    per-bucket step counts — they are returned as the per-bucket
    ``FleetResult`` list rather than forced into one ragged array.

    Returns ``(x_final, x0, results)``: (B, M) stitched estimates plus the
    per-bucket results in the same order as ``buckets``.
    """
    import numpy as np

    engine = run_fleet if engine is None else engine
    b_total = 1 + max(max(bk.nodes) for bk in buckets)
    m = buckets[0].inputs.c.shape[-1]
    x_final = np.zeros((b_total, m), np.float32)
    x0 = np.zeros((b_total, m), np.float32)
    results = []
    for bk in buckets:
        res = engine(bk.inputs, config, with_ticks=with_ticks)
        x_final[list(bk.nodes)] = np.asarray(res.x_final)
        x0[list(bk.nodes)] = np.asarray(res.x0)
        results.append(res)
    return jnp.asarray(x_final), jnp.asarray(x0), results
