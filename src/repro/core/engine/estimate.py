"""Initial-estimate stage: whole-trace X_0 solves (§4.2) for every path.

One gram-domain NNLS family, three entry points:

  ``fleet_initial_estimate``     batched over the node axis (segment paths);
  ``bucketed_initial_estimate``  one node, length-bucketed compile (serving
                                 admissions — see ``core.engine.buckets``);
  ``_node_init_gram``            the shared per-node gram/rhs contraction.

``_init_states`` turns a (B, M) X_0 into the batched Kalman start state —
the hand-off point between this stage and the filter stages.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.engine.types import Array, EngineConfig
from repro.core.kalman import KalmanState, kalman_init


def _gram_fn(backend: str) -> Callable | None:
    """Resolve the gram-assembly backend (None = XLA einsum)."""
    if backend == "auto":
        from repro.kernels.disagg_solve import default_backend

        backend = default_backend()
    if backend == "pallas":
        from repro.kernels.disagg_solve import disagg_gram

        # Off-TPU the kernel only runs in interpret mode (Python-speed;
        # for correctness work, which is why explicit backend="pallas"
        # still honors it rather than failing at compile time).
        return functools.partial(
            disagg_gram, interpret=jax.default_backend() != "tpu"
        )
    if backend == "xla":
        return None
    raise ValueError(f"unknown gram backend: {backend!r}")


def _node_init_gram(c_node: Array, w_node: Array) -> tuple[Array, Array]:
    """Whole-trace gram/rhs for one node via flat matmuls.

    The flat (S*n_w, M) contraction is used (rather than a stepwise einsum)
    because XLA keeps its reduction order identical under vmap — the batched
    engine and the sequential oracle see bitwise-equal grams.
    """
    cf = c_node.reshape(-1, c_node.shape[-1])
    return cf.T @ cf, cf.T @ w_node.reshape(-1)


def fleet_initial_estimate(
    c: Array, w: Array, config: EngineConfig = EngineConfig(), *, gram_fn=None
) -> Array:
    """(B, M) statistical disaggregation X_0 per node (§4.2).

    Accepts (B, N, M)/(B, N) window blocks or (B, S, n_w, M)/(B, S, n_w)
    step blocks — grams are additive over windows either way — and runs one
    batched gram-domain NNLS, no per-node loop.
    """
    from repro.core.disaggregation import solve_nnls_gram

    m = c.shape[-1]
    eye = config.init_lam * jnp.eye(m, dtype=c.dtype)
    if gram_fn is None:
        if c.shape[0] == 1:
            # XLA lowers batch-1 contractions differently from both the
            # plain and batch-N forms; route through the plain form so a
            # one-node fleet still matches the sequential oracle bitwise.
            g1, r1 = _node_init_gram(c[0], w[0])
            return solve_nnls_gram(g1 + eye, r1, iters=config.init_iters)[None]
        gram, rhs = jax.vmap(_node_init_gram)(c, w)
    else:
        gram, rhs = gram_fn(c.reshape(c.shape[0], -1, m), w.reshape(w.shape[0], -1))
    return solve_nnls_gram(gram + eye, rhs, iters=config.init_iters)


def _init_states(x0: Array) -> KalmanState:
    """Batched ``kalman_init`` from a (B, M) initial estimate."""
    return jax.vmap(lambda x: kalman_init(x.shape[-1], x0=x))(x0)
