"""Packing stage: per-window host arrays → (B, S, n_w, ...) engine batches.

``pack_fleet_inputs`` is the one place the ragged-fleet pad-and-mask
contract is defined on the way *in* (its mask is then folded exactly once
by ``plan.resolve_plan``); the ``synthetic_*`` generators are the shared
input factories the equivalence tests and benchmarks both draw from, so
they exercise the same contract the real telemetry path does.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.core.engine.types import Array, FleetInputs


def synthetic_fleet(
    b: int, s: int, n_w: int, m: int, *, seed: int = 0, density: float = 0.2
) -> FleetInputs:
    """Randomized synthetic fleet batch: sparse contributions, true power
    plus noise.  Shared input generator for the equivalence tests and
    ``benchmarks/kernel_bench.py`` so both exercise the same contract."""
    import numpy as np

    rng = np.random.default_rng(seed)
    c = np.abs(rng.standard_normal((b, s, n_w, m))) * (
        rng.random((b, s, n_w, m)) > 1 - density
    )
    x_true = np.abs(rng.standard_normal((b, m))) * 20.0 + 2.0
    w = np.einsum("bsnm,bm->bsn", c, x_true) + 0.1 * rng.standard_normal((b, s, n_w))
    a = (rng.random((b, s, m)) > 0.5) * rng.integers(0, 4, (b, s, m))
    lat = np.abs(rng.standard_normal((b, s, m)))
    return FleetInputs(
        c=jnp.asarray(c, jnp.float32),
        w=jnp.asarray(np.maximum(w, 0.0), jnp.float32),
        a=jnp.asarray(a, jnp.float32),
        lat_sum=jnp.asarray(lat * a, jnp.float32),
        lat_sumsq=jnp.asarray(lat**2 * a, jnp.float32),
    )


def pack_fleet_inputs(
    c_windows: Array,    # (B, N, M) per-node contribution matrices
    w_windows: Array,    # (B, N) per-node idle-adjusted power
    a_windows: Array,    # (B, N, M) per-node invocation counts
    lat_sum_w: Array,    # (B, N, M) per-window latency sums
    lat_sumsq_w: Array,  # (B, N, M)
    *,
    step_windows: int,
    lengths: Sequence[int] | Array | None = None,
    fn_lengths: Sequence[int] | Array | None = None,
    strict: bool = False,
) -> FleetInputs:
    """Group per-window arrays into (B, S, n_w, ...) Kalman-step blocks,
    padding + masking ragged fleets instead of truncating them.

    Each node ``i`` contributes ``lengths[i]`` real windows (arrays are
    padded to a common N on the window axis; values past a node's length
    are ignored).  A Kalman update is defined over a full ``step_windows``
    block, so node ``i`` yields ``S_i = lengths[i] // step_windows`` steps
    — the sub-step remainder feeds no update, exactly like the per-node
    profiler's ``segment_plan`` tail — and the fleet packs to
    ``S = max_i S_i`` steps with a ``(B, S, n_w)`` validity mask marking
    each node's real ticks.  Everything outside a node's valid region is
    zeroed and masked, so junk in the padded tail of the caller's arrays
    can never leak into grams, innovations, or attribution.  A uniform
    fleet whose window count divides ``step_windows`` packs with
    ``mask=None`` — the dense engines' exact pre-ragged inputs.

    Args:
      c_windows/w_windows: (B, N, M)/(B, N) per-window contributions/power.
      a_windows/lat_sum_w/lat_sumsq_w: (B, N, M) per-window invocation
        counts and latency moments (summed into per-step statistics).
      step_windows: n_w, ticks per Kalman step.
      lengths: per-node real window counts; ``None`` means every node has
        all N windows.
      fn_lengths: per-node real *function* counts over the padded M axis
        (heterogeneous fleets whose nodes host different function sets pad
        M to the fleet max); ``None`` means every node hosts all M
        functions.  Sets ``FleetInputs.fn_mask`` so the engines zero the
        padded functions' statistics and output rows exactly.
      strict: require the old equal-length contract — every node must have
        exactly N windows and N must divide ``step_windows`` evenly;
        anything ragged raises ``ValueError`` instead of being masked.

    Returns:
      ``FleetInputs`` with S = max_i(lengths[i] // step_windows) steps and
      ``mask`` set iff the fleet is actually ragged.
    """
    b, n, m = c_windows.shape
    if lengths is None:
        lengths_arr = jnp.full((b,), n, jnp.int32)
    else:
        import numpy as np

        lengths_np = np.asarray(lengths, np.int64)
        if lengths_np.shape != (b,):
            raise ValueError(
                f"lengths must have shape ({b},), got {lengths_np.shape}"
            )
        if np.any(lengths_np < 0) or np.any(lengths_np > n):
            raise ValueError(
                f"lengths must lie in [0, {n}] (the padded window axis); "
                f"got {lengths_np.tolist()}"
            )
        lengths_arr = jnp.asarray(lengths_np, jnp.int32)
    if strict:
        import numpy as np

        lens = np.asarray(lengths_arr)
        if np.any(lens != n) or n % step_windows != 0:
            raise ValueError(
                f"pack_fleet_inputs(strict=True) requires every node to "
                f"have exactly N={n} windows with N divisible by "
                f"step_windows={step_windows}; got lengths="
                f"{lens.tolist()} (use strict=False for pad-and-mask)"
            )
    s_nodes = lengths_arr // step_windows            # (B,) full steps per node
    s = int(jnp.max(s_nodes))
    if s == 0:
        raise ValueError(
            f"need at least step_windows={step_windows} windows on at "
            f"least one node, got lengths "
            f"{jnp.asarray(lengths_arr).tolist()} (N={n})"
        )
    n_used = s * step_windows
    if n < n_used:
        raise ValueError(f"window axis N={n} shorter than S*n_w={n_used}")
    # Per-node valid region: the first S_i full steps' ticks, nothing else.
    tick_valid = (
        jnp.arange(n_used, dtype=jnp.int32)[None, :]
        < (s_nodes * step_windows)[:, None]
    )                                                # (B, n_used) bool
    mask = tick_valid.reshape(b, s, step_windows).astype(jnp.float32)
    mv = mask[..., None]
    fn_mask = None
    if fn_lengths is not None:
        import numpy as np

        fn_lens = np.asarray(fn_lengths, np.int64)
        if fn_lens.shape != (b,):
            raise ValueError(
                f"fn_lengths must have shape ({b},), got {fn_lens.shape}"
            )
        if np.any(fn_lens < 0) or np.any(fn_lens > m):
            raise ValueError(
                f"fn_lengths must lie in [0, {m}] (the padded function "
                f"axis); got {fn_lens.tolist()}"
            )
        if np.any(fn_lens != m):
            fn_mask = jnp.asarray(
                np.arange(m)[None, :] < fn_lens[:, None], jnp.float32
            )
    grp = lambda x: x[:, :n_used].reshape(b, s, step_windows, m)
    inputs = FleetInputs(
        c=grp(c_windows) * mv,
        w=w_windows[:, :n_used].reshape(b, s, step_windows) * mask,
        a=(grp(a_windows) * mv).sum(axis=2),
        lat_sum=(grp(lat_sum_w) * mv).sum(axis=2),
        lat_sumsq=(grp(lat_sumsq_w) * mv).sum(axis=2),
        mask=None if bool(jnp.all(tick_valid)) else mask,
        fn_mask=fn_mask,
    )
    return inputs


def synthetic_ragged_windows(
    b: int, n: int, m: int, *, lengths: Sequence[int], seed: int = 0,
    density: float = 0.2,
):
    """Per-*window* synthetic fleet arrays for ragged packing.

    The window-granular twin of ``synthetic_fleet``: returns
    ``(c, w, a, lat_sum, lat_sumsq)`` with shape (B, N, ...) plus the
    given per-node ``lengths``, ready for ``pack_fleet_inputs``.  Windows
    past each node's length are filled with *non-zero junk* on purpose —
    the pad-and-mask contract says they must not be able to leak into any
    result, and the ragged tests and ``benchmarks/ragged_fleet.py`` both
    rely on that property being exercised, not vacuously true.
    """
    import numpy as np

    rng = np.random.default_rng(seed)
    c = np.abs(rng.standard_normal((b, n, m))) * (rng.random((b, n, m)) > 1 - density)
    x_true = np.abs(rng.standard_normal((b, m))) * 20.0 + 2.0
    w = np.maximum(
        np.einsum("bnm,bm->bn", c, x_true) + 0.1 * rng.standard_normal((b, n)), 0.0
    )
    a = ((rng.random((b, n, m)) > 0.8) * rng.integers(0, 3, (b, n, m))).astype(np.float32)
    lat = np.abs(rng.standard_normal((b, n, m)))
    ls, lq = lat * a, lat**2 * a
    # Junk beyond each node's real windows: masking must erase it exactly.
    for i, li in enumerate(lengths):
        c[i, li:] = 7.7
        w[i, li:] = 123.0
        a[i, li:] = 3.0
        ls[i, li:] = 9.9
        lq[i, li:] = 9.9
    return (
        jnp.asarray(c, jnp.float32),
        jnp.asarray(w, jnp.float32),
        jnp.asarray(a, jnp.float32),
        jnp.asarray(ls, jnp.float32),
        jnp.asarray(lq, jnp.float32),
    )
