"""Segment filter stages: batched, gram-hoisted, and sequential-oracle runs.

Each engine here is ``resolve_plan`` → a filter stage → ``finish_result``
(``core.engine.plan``): the shared stages own mask folding, init-block
defaults, conserved attribution, and the fn-axis output fold, so this
module contains only what actually differs between the paths —

    ``run_fleet``            vmap over nodes + ``lax.scan`` over steps on the
                             raw (B, S, n_w, M) window blocks; numerically
                             identical to the sequential reference.
    ``run_fleet_gram``       the O(M^2)-per-step variant: window statistics
                             are hoisted into one batched gram pass first
                             (Pallas kernel on TPU, XLA einsum elsewhere),
                             so the scan never touches the window dimension.
    ``run_fleet_sequential`` the seed-semantics oracle: Python loops over
                             nodes and steps calling ``kalman_step``.  Tests
                             pin the batched paths against it; benchmarks
                             time the batched paths against it.

``mesh`` dispatches through ``core.engine.sharding`` (each device re-enters
the unsharded engine on its local node block).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine.estimate import _init_states, _node_init_gram
from repro.core.engine.plan import finish_result, resolve_plan
from repro.core.engine.sharding import _run_sharded
from repro.core.engine.types import Array, EngineConfig, FleetInputs, FleetResult
from repro.core.kalman import (
    kalman_init,
    kalman_step,
    precompute_step_inputs,
    run_kalman,
    run_kalman_fleet,
    run_kalman_fleet_gram,
    run_kalman_gram,
)


def run_fleet(
    inputs: FleetInputs,
    config: EngineConfig = EngineConfig(),
    *,
    init_c: Array | None = None,
    init_w: Array | None = None,
    with_ticks: bool = True,
    mesh=None,
) -> FleetResult:
    """The batched engine: three fleet-wide jitted stages, no Python loops.

    Stage 1 solves every node's whole-trace X_0 in one batched NNLS (over
    ``init_c``/``init_w`` — a dedicated N_init window block, profiler-style
    — when given, else over all steps); stage 2 — the hot loop — filters
    all B nodes x S steps x n_w ticks in a single jitted ``vmap``+``scan``
    call; stage 3 computes conserved per-tick attribution.  The stages are
    separate jit boundaries (rather than one fused program) so each
    compiles identically to the sequential oracle's building blocks — which
    is what lets tests pin batched == sequential to float-reassociation
    noise.

    With ``mesh`` (a ``distributed.sharding.FleetMesh``) the node axis is
    sharded over the mesh devices via ``shard_map``: each device runs these
    same stages on its local node block, collective-free, pinned to the
    unsharded result at 1e-5 (tests/test_sharded_fleet.py).

    Ragged fleets: with ``inputs.mask`` set, masked ticks are folded to
    zero telemetry (``_apply_mask``) before any stage runs — they feed no
    gram/innovation statistics, attribute exactly 0 W in ``tick_power``,
    and fully-masked steps leave the per-node Kalman state untouched (the
    trajectory repeats the frozen estimate)."""
    if mesh is not None:
        return _run_sharded(run_fleet, inputs, config, init_c, init_w, with_ticks, mesh)
    plan = resolve_plan(inputs, config, init_c=init_c, init_w=init_w)
    inputs = plan.inputs
    x0 = plan.initial_estimate()
    if inputs.c.shape[0] == 1:
        # Batch-1 vmap lowers contractions differently; keep the one-node
        # fleet on the plain scan so it matches the oracle bitwise.
        final1, traj1 = run_kalman(
            kalman_init(inputs.c.shape[-1], x0=x0[0]), inputs.c[0], inputs.w[0],
            inputs.a[0], inputs.lat_sum[0], inputs.lat_sumsq[0], config.kalman,
        )
        final = jax.tree.map(lambda l: l[None], final1)
        traj = traj1[None]
    else:
        final, traj = run_kalman_fleet(
            _init_states(x0), inputs.c, inputs.w, inputs.a,
            inputs.lat_sum, inputs.lat_sumsq, config.kalman,
        )
    return finish_result(
        plan, final_state=final, traj=traj, x0=x0, with_ticks=with_ticks
    )


def run_fleet_gram(
    inputs: FleetInputs,
    config: EngineConfig = EngineConfig(),
    *,
    init_c: Array | None = None,
    init_w: Array | None = None,
    with_ticks: bool = True,
    mesh=None,
) -> FleetResult:
    """Gram-hoisted engine: window statistics reduced once (Pallas kernel on
    TPU, XLA einsum elsewhere), then an O(M^2)-per-step fleet scan that
    never touches the window dimension.  Same update rule as ``run_fleet``;
    equal up to float reassociation of the hoisted contractions.  ``mesh``
    shards the node axis exactly as in ``run_fleet``; ``inputs.mask``
    makes the fleet ragged exactly as in ``run_fleet`` (masked ticks are
    zeroed *before* the gram hoist, so they drop out of the hoisted
    statistics too)."""
    if mesh is not None:
        return _run_sharded(
            run_fleet_gram, inputs, config, init_c, init_w, with_ticks, mesh
        )
    plan = resolve_plan(
        inputs, config, init_c=init_c, init_w=init_w, use_backend=True
    )
    inputs = plan.inputs
    x0 = plan.initial_estimate()
    step_inputs = precompute_step_inputs(
        inputs.c, inputs.w, inputs.a, inputs.lat_sum, inputs.lat_sumsq,
        config.kalman, gram_fn=plan.gram_fn,
    )
    if inputs.c.shape[0] == 1:
        final1, traj1 = run_kalman_gram(
            kalman_init(inputs.c.shape[-1], x0=x0[0]),
            jax.tree.map(lambda l: l[0], step_inputs),
            config.kalman,
        )
        final = jax.tree.map(lambda l: l[None], final1)
        traj = traj1[None]
    else:
        final, traj = run_kalman_fleet_gram(_init_states(x0), step_inputs, config.kalman)
    return finish_result(
        plan, final_state=final, traj=traj, x0=x0, with_ticks=with_ticks
    )


def run_fleet_sequential(
    inputs: FleetInputs,
    config: EngineConfig = EngineConfig(),
    *,
    init_c: Array | None = None,
    init_w: Array | None = None,
    with_ticks: bool = True,
) -> FleetResult:
    """Sequential-reference oracle (seed semantics, Python loops).

    Loops nodes x steps calling the per-step ``kalman_step`` exactly as the
    seed's per-node profiler did; used by tests as the ground truth the
    batched paths must reproduce and by benchmarks as the baseline.
    Ragged fleets go through the same ``_apply_mask`` fold as the batched
    engines (via ``resolve_plan``), so the oracle defines masked semantics
    too.  Its X_0 stage stays a per-node loop over the plan's init block —
    the reference the batched NNLS is pinned against, not a consumer of
    it."""
    from repro.core.disaggregation import solve_nnls_gram

    plan = resolve_plan(inputs, config, init_c=init_c, init_w=init_w)
    inputs = plan.inputs

    b, s, n_w, m = inputs.c.shape
    ic, iw = plan.init_c, plan.init_w
    eye = config.init_lam * jnp.eye(m, dtype=jnp.float32)
    x0s = []
    for i in range(b):
        gram, rhs = _node_init_gram(ic[i], iw[i])
        x0s.append(solve_nnls_gram(gram + eye, rhs, iters=config.init_iters))
    x0 = jnp.stack(x0s)
    finals, trajs = [], []
    for i in range(b):
        state = kalman_init(m, x0=x0[i])
        xs = []
        for j in range(s):
            state, x = kalman_step(
                state,
                inputs.c[i, j],
                inputs.w[i, j],
                inputs.a[i, j],
                inputs.lat_sum[i, j],
                inputs.lat_sumsq[i, j],
                config.kalman,
            )
            xs.append(x)
        finals.append(state)
        trajs.append(jnp.stack(xs))
    traj = jnp.stack(trajs)
    state = jax.tree.map(lambda *leaves: jnp.stack(leaves), *finals)
    return finish_result(
        plan, final_state=state, traj=traj, x0=x0, with_ticks=with_ticks
    )
