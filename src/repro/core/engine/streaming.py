"""Streaming filter stage: one jitted update per telemetry tick.

``fleet_step`` is the live metering hot path — a single
``(FleetStreamState, FleetStep) -> (FleetStreamState, TickAttribution)``
update per tick, with gram/rhs/innovation statistics accumulating inside
the carried state and the Kalman update firing at step boundaries via
``lax.cond``, so the control plane can meter, price, and cap *live*
instead of replaying a finished segment (docs/streaming.md).
``run_fleet_stream`` is the same step re-expressed as ``lax.scan`` over a
segment — one code path for online and offline, pinned against
``run_fleet`` and the sequential oracle through the shared
``resolve_plan``/``finish_result`` stages (``core.engine.plan``).
``fleet_stream_reset_slots`` is the slot pool's claim primitive
(docs/serving.md).  Mesh dispatch lives in ``core.engine.sharding``; the
per-node liveness fold lives in ``core.engine.masking``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.engine.estimate import _init_states
from repro.core.engine.masking import _apply_mask, fold_step_valid
from repro.core.engine.plan import finish_result, resolve_plan
from repro.core.engine.sharding import (
    _run_sharded,
    _sharded_reset_runner,
    _sharded_step_runner,
)
from repro.core.engine.attribution import _conserved_split
from repro.core.engine.types import (
    Array,
    EngineConfig,
    FleetInputs,
    FleetResult,
    FleetStep,
    FleetStreamState,
    TickAttribution,
)
from repro.core.kalman import KalmanState, kalman_step_gram, precompute_step_inputs


def fleet_stream_init(
    x0: Array, n_w: int, config: EngineConfig = EngineConfig(), *, mesh=None
) -> FleetStreamState:
    """Initial streaming state from a (B, M) whole-trace estimate X_0.

    Args:
      x0: (B, M) initial estimate — from ``fleet_initial_estimate`` over the
        init segment (§4.2), a previous session's final state, or another
        node's estimate (warm handoff *at a step boundary*; a handoff into
        a slot whose previous tenant wrote ticks earlier in the current
        partial step must go through ``fleet_stream_reset_slots``, which
        also clears the slot's ring-buffer rows).
      n_w: ticks per Kalman step (sizes the partial-step ring buffer; must
        match the ``n_w`` later passed to ``fleet_step``).
      config: engine configuration.
      mesh: optional ``distributed.sharding.FleetMesh``; the state is placed
        sharded over the node axis (scalar counters replicated), so the
        donated buffers live distributed for the whole stream — pass the
        same mesh to every subsequent ``fleet_step``.

    Returns:
      ``FleetStreamState`` with an empty partial step.
    """
    b, m = x0.shape
    zf = functools.partial(jnp.zeros, dtype=jnp.float32)
    # Copy x0: the returned state is donated by ``fleet_step``, and the
    # filter's initial x would otherwise alias the caller's buffer.
    x0 = jnp.array(x0, jnp.float32, copy=True)
    state = FleetStreamState(
        kalman=_init_states(x0),
        c_buf=zf((b, n_w, m)),
        w_buf=zf((b, n_w)),
        a=zf((b, m)),
        lat_sum=zf((b, m)),
        lat_sumsq=zf((b, m)),
        tick_in_step=jnp.zeros((), jnp.int32),
        step_idx=jnp.zeros((), jnp.int32),
    )
    if mesh is not None:
        mesh.validate(b)
        state = mesh.put(state)
    return state


def _fleet_step_impl(
    state: FleetStreamState,
    step: FleetStep,
    config: EngineConfig,
    mesh=None,
) -> tuple[FleetStreamState, TickAttribution]:
    """One streaming tick: buffer the tick, update at step boundaries.

    The step length n_w is the ring buffer's static shape
    (``state.c_buf.shape[1]``, fixed by ``fleet_stream_init``).  Mid-step
    ticks are O(B M): the tick's contribution/power rows are written in
    place into the carried ring buffer (the donated state makes these true
    in-place updates) and the invocation/latency sums accumulate.  Every
    ``n_w``-th tick closes the step behind ``lax.cond`` — only the taken
    branch executes — reducing the full buffer through the segment gram
    engine's own ``precompute_step_inputs`` and running the batched
    gram-domain Kalman update: the same update rule as ``run_fleet_gram``.

    With ``mesh`` the whole update runs under ``shard_map`` over the node
    axis: the carried state stays sharded on-device (each device owns its
    node block's ring buffer and filter state), the per-tick math is
    collective-free, and the replicated ``tick_in_step``/``step_idx``
    counters drive the *same* boundary ``lax.cond`` on every device.

    Ragged fleets (``step.valid``): invalid node-ticks write zero rows
    into the ring buffer and add nothing to the invocation sums, so the
    boundary update reduces each node's step over exactly its valid ticks
    — the same semantics as the segment engines' ``_apply_mask``, folded
    by the same masking stage (``masking.fold_step_valid``) — and their
    attribution is exactly zero.  ``valid`` is data: a stream keeps its
    single trace as nodes come and go.
    """
    if mesh is not None:
        step_fn = _sharded_step_runner(
            _fleet_step_impl, config, mesh, step.valid is not None
        )
        return step_fn(state, step)
    step = fold_step_valid(step)
    kcfg = config.kalman
    n_w = state.c_buf.shape[1]
    c_buf = jax.lax.dynamic_update_index_in_dim(
        state.c_buf, step.c, state.tick_in_step, axis=1
    )
    w_buf = jax.lax.dynamic_update_index_in_dim(
        state.w_buf, step.w, state.tick_in_step, axis=1
    )
    a = state.a + step.a
    lat_sum = state.lat_sum + step.lat_sum
    lat_sumsq = state.lat_sumsq + step.lat_sumsq
    tick = state.tick_in_step + 1
    boundary = tick >= n_w

    acc = (a, lat_sum, lat_sumsq)

    def do_update(operand):
        kal, (a, ls, lq) = operand
        inp = precompute_step_inputs(c_buf, w_buf, a, ls, lq, kcfg)
        kal, _ = jax.vmap(lambda st, i: kalman_step_gram(st, i, kcfg))(kal, inp)
        return kal, jax.tree.map(jnp.zeros_like, (a, ls, lq))

    def no_update(operand):
        return operand

    kal, acc = jax.lax.cond(boundary, do_update, no_update, (state.kalman, acc))
    a, lat_sum, lat_sumsq = acc

    # Causal conserved attribution under the freshest estimate.
    tick_power, unattributed = _conserved_split(step.c * kal.x, step.w, config.delta)
    att = TickAttribution(
        tick_power=tick_power,
        unattributed=unattributed,
        x=kal.x,
        step_completed=boundary,
    )
    new_state = FleetStreamState(
        kalman=kal, c_buf=c_buf, w_buf=w_buf,
        a=a, lat_sum=lat_sum, lat_sumsq=lat_sumsq,
        tick_in_step=jnp.where(boundary, 0, tick),
        step_idx=state.step_idx + boundary.astype(jnp.int32),
    )
    return new_state, att


fleet_step = functools.partial(
    jax.jit, static_argnames=("config", "mesh"), donate_argnums=(0,)
)(_fleet_step_impl)
fleet_step.__doc__ = """Jitted streaming tick update (donates ``state``).

``fleet_step(state, step, config=..., mesh=...)`` — the live metering hot
path.  ``config`` and ``mesh`` are static and the step length n_w comes
from the state's ring buffer shape (set by ``fleet_stream_init``), so
there is one trace per (fleet shape, config, mesh, has-valid) tuple,
reused for every subsequent tick — ``step.valid``'s *values* are data, so
ragged fleets with changing liveness never retrace; the retracing guards
in tests/test_streaming_engine.py, tests/test_sharded_fleet.py, and
tests/test_ragged_fleet.py pin this.
The input ``state`` is donated — its buffers are reused for the output
state (in place, and still sharded when a ``FleetMesh`` is active), so the
caller must rebind (``state, att = fleet_step(state, step, ...)``) and must
not touch the old state afterwards.
"""


def _reset_slots_local(
    state: FleetStreamState, reset: Array, x0: Array
) -> FleetStreamState:
    """Unsharded slot-reset body (see ``fleet_stream_reset_slots``)."""
    r = reset.astype(jnp.float32)                       # (B,) 1 = reset
    rb = r[:, None] > 0                                 # (B, 1)
    fresh = _init_states(x0.astype(jnp.float32))
    kal = KalmanState(
        x=jnp.where(rb, fresh.x, state.kalman.x),
        p=jnp.where(rb, fresh.p, state.kalman.p),
        seen=jnp.where(rb, fresh.seen, state.kalman.seen),
        lat_mean=jnp.where(rb, fresh.lat_mean, state.kalman.lat_mean),
        lat_m2=jnp.where(rb, fresh.lat_m2, state.kalman.lat_m2),
        lat_count=jnp.where(rb, fresh.lat_count, state.kalman.lat_count),
    )
    keep = 1.0 - r
    return FleetStreamState(
        kalman=kal,
        c_buf=state.c_buf * keep[:, None, None],
        w_buf=state.w_buf * keep[:, None],
        a=state.a * keep[:, None],
        lat_sum=state.lat_sum * keep[:, None],
        lat_sumsq=state.lat_sumsq * keep[:, None],
        tick_in_step=state.tick_in_step,
        step_idx=state.step_idx,
    )


def _reset_slots_impl(
    state: FleetStreamState, reset: Array, x0: Array, mesh=None
) -> FleetStreamState:
    if mesh is not None:
        return _sharded_reset_runner(_reset_slots_local, mesh)(state, reset, x0)
    return _reset_slots_local(state, reset, x0)


fleet_stream_reset_slots = functools.partial(
    jax.jit, static_argnames=("mesh",), donate_argnums=(0,)
)(_reset_slots_impl)
fleet_stream_reset_slots.__doc__ = """Jitted slot reset on a live stream (donates ``state``).

``fleet_stream_reset_slots(state, reset, x0, mesh=...)`` rewrites the rows
of every slot flagged in ``reset`` ((B,) 1.0/0.0, *data* — any combination
of slots reuses one trace) to a fresh tenant: the Kalman row becomes
``kalman_init`` of that slot's row of ``x0`` ((B, M); ignored where
``reset`` is 0), and the slot's ring-buffer rows and partial-step
invocation/latency accumulators are zeroed.  The global
``tick_in_step``/``step_idx`` counters are untouched — the new tenant
joins the fleet's step clock mid-step.

This is the claim primitive of the slot pool
(``core.sessions.SlotFleetSession.admit``) and the fix for the
die-and-rejoin leak: ``FleetStep.valid`` only zeroes ticks from the moment
a node goes invalid, so rows its slot wrote *earlier in the current
partial step* (a dead tenant's last ticks, or a previous tenant entirely)
would otherwise be reduced into the next boundary update of whoever holds
the slot next.  Resetting at claim time makes a reused slot
indistinguishable from one in a freshly initialized pool.

Like ``fleet_step`` the input ``state`` is donated and ``mesh`` is static:
callers must rebind, and with a ``FleetMesh`` the rewrite runs under
``shard_map`` with flags and ``x0`` sharded over the node axis.
"""


@functools.partial(jax.jit, static_argnames=("config",))
def _scan_stream(
    state: FleetStreamState, ticks: FleetStep, config: EngineConfig
) -> tuple[FleetStreamState, TickAttribution]:
    """``lax.scan`` of the streaming step over time-major (T, B, ...) ticks."""

    def body(st, tk):
        return _fleet_step_impl(st, tk, config)

    return jax.lax.scan(body, state, ticks)


def fleet_ticks(inputs: FleetInputs) -> FleetStep:
    """Explode segment inputs into a time-major (T, B, ...) tick stream.

    Inverse of the (B, S, n_w) step grouping: T = S * n_w ticks, with each
    step's invocation/latency statistics placed on its first *valid* tick
    (the engine only reads their sums at boundaries, so placement among
    the valid ticks is free — an invalid tick would drop them, since the
    streaming step zeroes invalid node-ticks).  A ragged ``inputs.mask``
    becomes the per-tick ``FleetStep.valid`` flags.  Feed the result to
    ``lax.scan`` (``run_fleet_stream``) or slice ticks off it to drive
    ``fleet_step`` one dispatch at a time.
    """
    return _fleet_ticks_masked(_apply_mask(inputs))


def _fleet_ticks_masked(inputs: FleetInputs) -> FleetStep:
    """``fleet_ticks`` body for inputs whose mask is already folded in
    (``run_fleet_stream`` folds once and reuses the result for the init
    solve, the tick stream, and the final attribution)."""
    b, s, n_w, m = inputs.c.shape
    tm = lambda x: jnp.moveaxis(x.reshape((b, s * n_w) + x.shape[3:]), 0, 1)
    if inputs.mask is None:
        first = jnp.zeros((b, s), jnp.int32)
        valid = None
    else:
        first = jnp.argmax(inputs.mask, axis=-1).astype(jnp.int32)  # (B, S)
        valid = tm(inputs.mask.astype(inputs.w.dtype))              # (T, B)
    onehot = jax.nn.one_hot(first, n_w, dtype=inputs.a.dtype)       # (B, S, n_w)
    place = lambda x: onehot[..., None] * x[:, :, None, :]
    return FleetStep(
        c=tm(inputs.c), w=tm(inputs.w), a=tm(place(inputs.a)),
        lat_sum=tm(place(inputs.lat_sum)), lat_sumsq=tm(place(inputs.lat_sumsq)),
        valid=valid,
    )


def run_fleet_stream(
    inputs: FleetInputs,
    config: EngineConfig = EngineConfig(),
    *,
    init_c: Array | None = None,
    init_w: Array | None = None,
    with_ticks: bool = True,
    mesh=None,
) -> FleetResult:
    """The segment engine re-expressed as a scan over the streaming step.

    Same contract as ``run_fleet``: X_0 from one batched NNLS over the init
    block, then ``lax.scan`` of ``_fleet_step_impl`` over all T = S * n_w
    ticks — the *identical* code path the online ``fleet_step`` runs, so the
    streaming engine is pinned to the segment engines by construction.  The
    returned trajectory collects the boundary-tick estimates; ``tick_power``
    uses the segment engine's smoothed-within-step attribution for
    comparability (the causal live variant is what ``fleet_step`` emits).

    Args:
      inputs: (B, S, n_w, M) step-grouped fleet batch; a ragged
        ``inputs.mask`` flows into per-tick ``FleetStep.valid`` flags via
        ``fleet_ticks`` (same masked semantics as ``run_fleet``).
      config: engine configuration (``backend`` is ignored here — streaming
        accumulation is tick-wise by definition).
      init_c/init_w: optional dedicated init block for X_0 (profiler-style);
        defaults to the whole segment.
      with_ticks: also compute (B, T, M) conserved per-tick attribution.
      mesh: optional ``distributed.sharding.FleetMesh``; shards the node
        axis over the mesh devices exactly as in ``run_fleet``.

    Returns:
      ``FleetResult`` with ``state`` holding the final *Kalman* state of the
      stream (identical pytree to the other engines').
    """
    if mesh is not None:
        return _run_sharded(
            run_fleet_stream, inputs, config, init_c, init_w, with_ticks, mesh
        )
    plan = resolve_plan(inputs, config, init_c=init_c, init_w=init_w)
    inputs = plan.inputs
    x0 = plan.initial_estimate()
    b, s, n_w, m = inputs.c.shape
    state0 = fleet_stream_init(x0, n_w, config)
    final, att = _scan_stream(state0, _fleet_ticks_masked(inputs), config)
    # Boundary ticks carry each step's post-update estimate: the trajectory.
    traj = jnp.moveaxis(att.x.reshape(s, n_w, b, m)[:, -1], 1, 0)  # (B, S, M)
    return finish_result(
        plan, final_state=final.kalman, traj=traj, x0=x0, with_ticks=with_ticks
    )
