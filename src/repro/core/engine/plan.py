"""FleetPlan: the engine package's single declarative composition point.

Every cross-cutting concern a fleet run needs — mask folding, init-block
defaults, the gram backend, per-tick attribution, the fn-axis output fold
— used to be re-threaded by hand through four engine paths (sequential
oracle, batched segment, gram-hoisted, streaming scan).  Here it is
resolved **once, as data**:

    plan = resolve_plan(inputs, config, init_c=..., init_w=...)
    x0 = plan.initial_estimate()
    ... engine-specific filter stage ...
    return finish_result(plan, final_state=..., traj=..., x0=...,
                         with_ticks=...)

``resolve_plan`` is the entry stage (mask fold + init defaults + backend),
``finish_result`` the exit stage (conserved attribution + fn-mask fold);
the only thing an engine path contributes in between is its filter.  The
mesh dispatch concern lives one stage over in ``core.engine.sharding``
(``_run_sharded`` re-enters the engine per local shard, where it resolves
a local plan), and the windowing layout shared with the session/profiler
layers is ``segment_plan`` below.  Because every stage is the same
function object across paths, the paths cannot drift — the bitwise/1e-5
pins in tests/test_batched_engine.py et al. are structural, not lucky.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.engine.attribution import tick_attribution
from repro.core.engine.estimate import _gram_fn, fleet_initial_estimate
from repro.core.engine.masking import _apply_mask, _mask_fn_axis
from repro.core.engine.types import (
    Array,
    EngineConfig,
    FleetInputs,
    FleetResult,
)


@dataclasses.dataclass(frozen=True, eq=False)
class FleetPlan:
    """One fleet run's resolved configuration — config + folded data, once.

    Built by ``resolve_plan`` and consumed by all four engine paths.  The
    fields are *post-fold*: ``inputs`` already has tick/fn masks folded in
    (``masking._apply_mask``), ``init_c``/``init_w`` are the resolved init
    block (the caller's dedicated block, else the folded segment itself —
    so a ragged fleet's padding can never leak into the init gram), and
    ``gram_fn`` is the resolved gram-assembly backend (None = XLA einsum;
    only the gram-hoisted path resolves one).
    """

    config: EngineConfig
    inputs: FleetInputs       # mask-folded batch (identity when dense)
    init_c: Array             # (B, ..., M) init-block contributions
    init_w: Array             # (B, ...) init-block target power
    gram_fn: Callable | None = None

    def initial_estimate(self) -> Array:
        """(B, M) whole-trace X_0 over the plan's init block (§4.2)."""
        return fleet_initial_estimate(
            self.init_c, self.init_w, self.config, gram_fn=self.gram_fn
        )


def resolve_plan(
    inputs: FleetInputs,
    config: EngineConfig,
    *,
    init_c: Array | None = None,
    init_w: Array | None = None,
    use_backend: bool = False,
) -> FleetPlan:
    """Resolve one fleet run into a ``FleetPlan`` (the shared entry stage).

    Folds the ragged masks into the data exactly once (the single
    definition of masked semantics, ``masking._apply_mask``), defaults the
    init block to the *folded* inputs, and — for the gram-hoisted path
    (``use_backend=True``) — resolves the configured gram backend.  Every
    engine path calls this before its filter stage, so concerns like
    fn-masking are written here instead of four times.
    """
    folded = _apply_mask(inputs)
    return FleetPlan(
        config=config,
        inputs=folded,
        init_c=folded.c if init_c is None else init_c,
        init_w=folded.w if init_w is None else init_w,
        gram_fn=_gram_fn(config.backend) if use_backend else None,
    )


def finish_result(
    plan: FleetPlan,
    *,
    final_state,
    traj: Array,
    x0: Array,
    with_ticks: bool,
) -> FleetResult:
    """Assemble a ``FleetResult`` from a filter stage's outputs (exit stage).

    Computes the conserved per-tick attribution over the plan's folded
    inputs (when ``with_ticks``) and applies the fn-axis output fold
    (``masking._mask_fn_axis``) — the two exit concerns every engine path
    shares, written once.  ``final_state`` is the batched final
    ``KalmanState``; its ``x`` is the final estimate.
    """
    tick_power = unattributed = None
    if with_ticks:
        tick_power, unattributed = tick_attribution(
            plan.inputs.c, plan.inputs.w, traj, delta=plan.config.delta
        )
    return _mask_fn_axis(
        FleetResult(
            x_final=final_state.x, x_trajectory=traj, x0=x0,
            tick_power=tick_power, unattributed=unattributed,
            state=final_state,
        ),
        plan.inputs.fn_mask,
    )


def segment_plan(cfg, duration: float) -> tuple[int, int, int, int]:
    """Window accounting for one profiling segment, shared by every path.

    ``cfg`` is any profiler-level config carrying ``delta`` /
    ``init_windows`` / ``step_windows`` (``core.profiler.ProfilerConfig``
    in practice — duck-typed so this layout stage stays below the
    orchestration layer).  Returns ``(n_windows, init_n, s, n_used)``:
    total delta windows, the N_init initial-estimate block, the number of
    full Kalman steps after it, and the windows actually consumed
    (``init_n + s * step_windows`` — the ragged tail past it feeds no
    Kalman update).  The per-node ``FaasMeterProfiler.profile``,
    ``fleet_profile_batched``, ``StreamingFleetSession``, and the control
    plane's ``profile_fleet`` fallback logic all derive their plan from
    here so they cannot disagree.
    """
    n_windows = int(round(duration / cfg.delta))
    init_n = min(cfg.init_windows, n_windows)
    s = max((n_windows - init_n) // cfg.step_windows, 0)
    return n_windows, init_n, s, init_n + s * cfg.step_windows
