"""Target stage: what signal the engines disaggregate (pure vs §4.3 combined).

The engines are target-agnostic: combined mode (§4.3) feeds them the
chip-subtracted 'rest' power instead of the idle-adjusted system signal.
Every profiling path — per-node, batched segment, and streaming — builds
its combined targets through these two helpers, so the mode cannot drift
between paths.  (The chip side is attributed by ``core.cpu_model``'s
fleet-batched counter model; the counter-model plumbing lives with the
session/profiler layers above, this module is only the target arithmetic
the jitted engines consume.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine.types import Array


@jax.jit
def fleet_rest_idle(chip_init: Array, idle_watts) -> Array:
    """Idle power of the non-chip components, per node (§4.3).

    Approximated as total idle minus the chip's observed floor over the
    N_init initial-estimate block:  ``max(idle - min(chip_init), 0)``.
    Using the init block (rather than the full segment) keeps the estimate
    identical across the per-node, batched, and *streaming* paths — the
    stream knows only the init windows when it must start producing
    combined targets — and never reads past the accounting segment.

    Args:
      chip_init: (..., N_init) chip power over the init block (one node or
        a (B, N_init) fleet).
      idle_watts: scalar or (...,) per-node total idle power.

    Returns:
      (...,) rest-side idle watts, traceable (no host sync).
    """
    return jnp.maximum(
        jnp.asarray(idle_watts, jnp.float32) - jnp.min(chip_init, axis=-1), 0.0
    )


@jax.jit
def combined_rest_target(w_sys: Array, chip: Array, rest_idle) -> Array:
    """Combined-mode (§4.3) disaggregation target: the 'rest' power.

    ``max(W_sys - W_chip - rest_idle, 0)`` — the chip side is modeled by
    the linear counter model, so the Kalman/NNLS engines disaggregate only
    what is left of the system signal.  Pure broadcasting: callers align
    ``rest_idle`` themselves (scalar, or ``(B, 1)`` against ``(B, N)``
    windows, or ``(B,)`` against per-tick ``(B,)`` power).  All three fleet
    engines and the per-node profiler build their combined targets through
    this single helper, so the mode cannot drift between paths.  Masked
    (padded) ticks arrive with ``w_sys = chip = 0`` after the engines'
    mask fold and therefore produce a zero target (``rest_idle >= 0``).
    """
    return jnp.maximum(w_sys - chip - rest_idle, 0.0)
