"""Engine data contracts: configs, batch inputs, stream state, results.

The leaf module of the layered engine package (docs/architecture.md,
"Layered engine"): every other ``core.engine`` stage — masking, plan
resolution, the segment/streaming filters, sharded dispatch, packing —
imports its types from here and nothing here imports any of them back.
Keeping the contracts in one dependency-free module is what lets a concern
like fn-masking live in exactly one stage: the stages compose through
these shapes instead of re-declaring them per code path.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax

from repro.core.kalman import KalmanConfig, KalmanState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine-wide configuration (hashable: doubles as a static jit arg).

    The same config drives all engine paths — segment, gram-hoisted, and
    streaming — so a pinned comparison never mixes hyperparameters.
    """

    kalman: KalmanConfig = KalmanConfig()
    delta: float = 1.0          # tick (window) length in seconds
    backend: str = "auto"       # auto | xla | pallas: gram-assembly backend
    init_iters: int = 400       # NNLS iterations for the whole-trace X_0
    init_ridge_lambda: float | None = None  # X_0 ridge; None -> kalman's

    @property
    def init_lam(self) -> float:
        """Ridge used for the initial X_0 solve (defaults to the Kalman's)."""
        return (
            self.kalman.ridge_lambda
            if self.init_ridge_lambda is None
            else self.init_ridge_lambda
        )


class FleetInputs(NamedTuple):
    """One fleet profiling batch: B nodes, S steps of n_w ticks, M functions.

    ``mask`` makes the fleet *ragged*: a ``(B, S, n_w)`` per-tick validity
    mask (1.0 = real telemetry tick, 0.0 = padding) whose flattened view is
    the ``(B, T)`` tick mask with ``T = S * n_w``.  ``mask=None`` means
    every tick is real (the dense fleet — the engines take the exact
    pre-ragged code path).  The mask is *data*, not a static shape: fleets
    with different rag patterns share one jit trace.  Masked ticks
    contribute exactly zero energy and masked-out steps freeze the Kalman
    state (see ``pack_fleet_inputs`` and docs/architecture.md,
    "Ragged fleets").

    ``fn_mask`` makes the *function* axis ragged too: a ``(B, M)`` per-node
    validity mask over the padded function axis (heterogeneous fleets whose
    nodes host different ``num_fns`` pad M to the fleet max).  Masked
    functions are folded to zero contributions/invocations before any
    engine stage and their rows of every estimate/attribution output are
    forced to exactly zero — a padded function can never absorb energy.
    Like ``mask`` it is data, not shape: mixes with different per-node
    function counts share one trace.
    """

    c: Array          # (B, S, n_w, M) contribution seconds per tick
    w: Array          # (B, S, n_w) idle-adjusted active power per tick (W)
    a: Array          # (B, S, M) invocation counts per step
    lat_sum: Array    # (B, S, M) summed latency per step
    lat_sumsq: Array  # (B, S, M) summed squared latency per step
    mask: Array | None = None  # (B, S, n_w) tick validity; None = all real
    fn_mask: Array | None = None  # (B, M) fn validity; None = all fns real


class FleetResult(NamedTuple):
    """Output of one fleet disaggregation (any engine path).

    ``tick_power``/``unattributed`` are None when computed with
    ``with_ticks=False``; otherwise ``tick_power.sum(-1) + unattributed``
    reproduces the measured per-tick power exactly (efficiency per tick).
    """

    x_final: Array        # (B, M) final per-function power estimate (W)
    x_trajectory: Array   # (B, S, M) per-step estimates
    x0: Array             # (B, M) whole-trace initial estimate
    tick_power: Array | None    # (B, T, M) conserved per-tick power (W)
    unattributed: Array | None  # (B, T) power in ticks with no activity
    state: KalmanState    # batched final filter state


class FleetStep(NamedTuple):
    """Inputs for ONE telemetry tick (delta window) across the fleet.

    Shapes: B nodes x M functions.  ``a``/``lat_sum``/``lat_sumsq`` carry the
    invocations *starting* in this tick; the engine only reads their running
    sums at Kalman-step boundaries, so any within-step placement that sums to
    the per-step statistics is equivalent (``fleet_ticks`` puts each step's
    totals on its first valid tick when replaying segment inputs).

    ``valid`` makes the tick *ragged*: a per-node liveness flag (1.0 = this
    node really produced this tick; 0.0 = the node's stream has ended, has
    not joined yet, or dropped the window).  Invalid node-ticks are folded
    to zero telemetry before they touch the ring buffer or the attribution
    split, so a dead node contributes nothing mid-step and its Kalman state
    freezes once a whole step passes without valid ticks — global stream
    time keeps advancing for the live nodes.  ``valid=None`` means every
    node is live (the dense fleet; identical trace to the pre-ragged step).
    """

    c: Array          # (B, M) contribution seconds within this tick
    w: Array          # (B,)   idle-adjusted active power this tick (W)
    a: Array          # (B, M) invocations starting in this tick
    lat_sum: Array    # (B, M) summed latency of those invocations (s)
    lat_sumsq: Array  # (B, M) summed squared latency (s^2)
    valid: Array | None = None  # (B,) node liveness this tick; None = all live


class FleetStreamState(NamedTuple):
    """Carried state of the streaming engine (the state-carry contract).

    Everything the per-tick update needs lives here — the batched Kalman
    filter state, a ring buffer of the current partial step's ticks, and the
    running invocation/latency statistics.  The jitted ``fleet_step``
    donates this state, so in steady streaming every buffer is updated in
    place and a tick is O(B M): two in-place row writes plus element-wise
    accumulation.  The O(B M^2) gram assembly and the NNLS/Kalman update run
    only at step boundaries (inside ``lax.cond``), contracting the full
    buffer with the *same* einsum as the segment gram engine — which is what
    keeps the streaming trajectory pinned to the segment paths.

    Invariants (see docs/streaming.md):
      - ``tick_in_step`` in [0, n_w); rows [0, tick_in_step) of
        ``c_buf``/``w_buf`` hold the current partial step (rows beyond it
        are stale — fully overwritten before the next boundary reads them);
      - ``a``/``lat_sum``/``lat_sumsq`` accumulate the partial step and are
        zeroed at each boundary;
      - ``step_idx`` counts completed Kalman steps.
    """

    kalman: KalmanState  # batched filter state, leading node axis B
    c_buf: Array         # (B, n_w, M) contribution rows of the partial step
    w_buf: Array         # (B, n_w)    power ticks of the partial step
    a: Array             # (B, M)      invocations so far in partial step
    lat_sum: Array       # (B, M)
    lat_sumsq: Array     # (B, M)
    tick_in_step: Array  # ()          int32 ticks in the partial step
    step_idx: Array      # ()          int32 completed Kalman steps


class TickAttribution(NamedTuple):
    """Live per-tick output of the streaming engine.

    ``tick_power`` is the *causal* conserved attribution: this tick's
    measured power split over the functions running in it, proportional to
    ``c * x`` under the latest available estimate (post-update on boundary
    ticks, the carried estimate mid-step).  It satisfies
    ``tick_power.sum(-1) + unattributed == w`` by construction — the same
    efficiency property as the segment engine's ``tick_attribution``, which
    differs only in using the step's final estimate for *all* its ticks
    (smoothed-within-step; see docs/streaming.md).
    """

    tick_power: Array     # (B, M) conserved per-tick power (W)
    unattributed: Array   # (B,)   power in ticks with no activity (W)
    x: Array              # (B, M) estimate after processing this tick (W)
    step_completed: Array  # ()    bool: did this tick close a Kalman step
