"""Azure-Functions-style workload generation (paper §2.1, §6; trace [73]).

The Azure trace's salient statistics, reproduced here:

- inter-arrival times are heavy-tailed across functions (0.01 s .. 1 day);
  we draw per-function mean IATs from a log-normal spanning the requested
  load range, and per-invocation IATs from the chosen arrival process;
- execution times range 0.1 s .. 100 s and are function-specific
  (log-normal around each FunctionSpec's mean with its CoV);
- arrival processes: Poisson (exponential IATs), bursty (Markov-modulated
  on/off), or closed-loop (next starts after previous ends, Fig. 2a's shape).

Generation is numpy (host-side data plane); everything downstream is JAX.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.workload.functions import FunctionRegistry
from repro.workload.trace import InvocationTrace


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    duration_s: float = 1800.0        # paper: 30-minute traces
    load: float = 1.0                 # target utilization scale (1.0 ~ 100 %)
    arrival: str = "poisson"          # poisson | bursty | closed
    burst_on_s: float = 30.0          # bursty: mean on-period
    burst_off_s: float = 20.0         # bursty: mean off-period
    burst_factor: float = 4.0         # rate multiplier during bursts
    concurrency: int = 1              # closed-loop: parallel loops per fn
    iat_spread: float = 1.0           # log-normal sigma of per-fn mean IATs
    seed: int = 0
    max_invocations: int = 200_000


def _fn_rates(registry: FunctionRegistry, cfg: WorkloadConfig, rng) -> np.ndarray:
    """Per-function arrival rates targeting the requested load.

    Load ~= sum_j rate_j * latency_j (expected concurrent invocations).
    Heavy-tailed heterogeneity enters through log-normal rate multipliers.
    """
    m = len(registry)
    lat = np.array([s.mean_latency_s for s in registry.specs])
    mult = rng.lognormal(0.0, cfg.iat_spread, size=m)
    base = mult / np.sum(mult * lat)  # sum(base * lat) == 1 concurrent
    return base * cfg.load * max(m, 1) / 2.0


def generate_trace(
    registry: FunctionRegistry, cfg: WorkloadConfig = WorkloadConfig()
) -> InvocationTrace:
    """Sample an invocation trace for the registry under ``cfg``."""
    rng = np.random.default_rng(cfg.seed)
    fn_ids, starts, ends = [], [], []

    if cfg.arrival == "closed":
        for j, spec in enumerate(registry.specs):
            for c in range(cfg.concurrency):
                t = rng.uniform(0, spec.mean_latency_s)
                while t < cfg.duration_s:
                    dur = _latency(rng, spec)
                    fn_ids.append(j)
                    starts.append(t)
                    ends.append(min(t + dur, cfg.duration_s))
                    t += dur + rng.exponential(0.05 * spec.mean_latency_s)
    else:
        rates = _fn_rates(registry, cfg, rng)
        for j, spec in enumerate(registry.specs):
            t = 0.0
            rate = max(rates[j], 1e-6)
            burst_state, state_left = True, rng.exponential(cfg.burst_on_s)
            while t < cfg.duration_s:
                r = rate
                if cfg.arrival == "bursty":
                    r = rate * (cfg.burst_factor if burst_state else 1.0 / cfg.burst_factor)
                iat = rng.exponential(1.0 / r)
                if cfg.arrival == "bursty":
                    state_left -= iat
                    if state_left <= 0:
                        burst_state = not burst_state
                        state_left = rng.exponential(
                            cfg.burst_on_s if burst_state else cfg.burst_off_s
                        )
                t += iat
                if t >= cfg.duration_s:
                    break
                dur = _latency(rng, spec)
                fn_ids.append(j)
                starts.append(t)
                ends.append(min(t + dur, cfg.duration_s))

    k = len(fn_ids)
    if k > cfg.max_invocations:
        raise ValueError(f"trace too large: {k} invocations")
    order = np.argsort(starts) if k else np.array([], np.int64)
    return InvocationTrace(
        fn_id=np.array(fn_ids, np.int32)[order],
        start=np.array(starts, np.float32)[order],
        end=np.array(ends, np.float32)[order],
        num_fns=len(registry),
        duration=cfg.duration_s,
        fn_names=registry.names,
    )


def fleet_traces(
    registry: FunctionRegistry, cfg: WorkloadConfig, num_nodes: int
) -> list[InvocationTrace]:
    """Per-node Azure-style traces for a fleet replay.

    Node ``i`` draws from ``cfg`` with ``seed + i`` — independent arrival
    processes with identical load statistics, the trace-scale input to
    ``EnergyFirstControlPlane.profile_fleet(control=...)`` and the
    control-loop benchmark.  Deterministic: the same (cfg, num_nodes) gives
    bitwise-identical traces.
    """
    return [
        generate_trace(registry, dataclasses.replace(cfg, seed=cfg.seed + i))
        for i in range(num_nodes)
    ]


def _latency(rng, spec) -> float:
    """Log-normal latency with the spec's mean and CoV."""
    cov = max(spec.latency_cov, 1e-3)
    sigma2 = np.log(1.0 + cov * cov)
    mu = np.log(spec.mean_latency_s) - 0.5 * sigma2
    return float(rng.lognormal(mu, np.sqrt(sigma2)))
