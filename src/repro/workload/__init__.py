"""FaaS workload substrate: function registry, traces, Azure-style generation."""

from repro.workload.functions import FunctionSpec, FunctionRegistry, paper_functions, arch_functions
from repro.workload.trace import InvocationTrace, concat_traces, drop_function, pad_trace
from repro.workload.azure import WorkloadConfig, generate_trace

__all__ = [
    "FunctionSpec",
    "FunctionRegistry",
    "paper_functions",
    "arch_functions",
    "InvocationTrace",
    "concat_traces",
    "drop_function",
    "pad_trace",
    "WorkloadConfig",
    "generate_trace",
]
