"""Invocation traces: the (fn_id, start, end) triples everything consumes.

A trace T is characterized by its function set S, per-function IAT CDFs, and
duration (paper §5.1).  Marginal-energy ground truth needs *nearly identical*
paired traces T(S) and T(S - f): ``drop_function`` removes one function's
invocations while leaving every other invocation bit-identical, which is
exactly the paper's protocol (the remaining workload is unchanged; only f's
marginal contribution differs).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class InvocationTrace:
    """Flat invocation arrays; fn_id < 0 entries are padding."""

    fn_id: np.ndarray    # (K,) int32
    start: np.ndarray    # (K,) float32 seconds
    end: np.ndarray      # (K,) float32 seconds
    num_fns: int
    duration: float
    fn_names: list[str] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.fn_id = np.asarray(self.fn_id, np.int32)
        self.start = np.asarray(self.start, np.float32)
        self.end = np.asarray(self.end, np.float32)

    @property
    def num_invocations(self) -> int:
        return int(np.sum(self.fn_id >= 0))

    def invocations_of(self, fn: int) -> int:
        return int(np.sum(self.fn_id == fn))

    def mean_latency(self) -> np.ndarray:
        """(M,) mean warm latency per function."""
        out = np.zeros(self.num_fns, np.float32)
        for j in range(self.num_fns):
            mask = self.fn_id == j
            if mask.any():
                out[j] = float(np.mean(self.end[mask] - self.start[mask]))
        return out

    def sorted_by_start(self) -> "InvocationTrace":
        order = np.argsort(np.where(self.fn_id >= 0, self.start, np.inf), kind="stable")
        return dataclasses.replace(
            self, fn_id=self.fn_id[order], start=self.start[order], end=self.end[order]
        )


def drop_function(trace: InvocationTrace, fn: int) -> InvocationTrace:
    """T(S - f): identical trace with function ``fn``'s invocations removed
    (marked as padding so array shapes — and jit caches — are preserved)."""
    mask = trace.fn_id == fn
    fn_id = np.where(mask, -1, trace.fn_id).astype(np.int32)
    return dataclasses.replace(trace, fn_id=fn_id)


def concat_traces(a: InvocationTrace, b: InvocationTrace, gap: float = 0.0) -> InvocationTrace:
    """Concatenate b after a (for dynamic active-set workloads, Fig. 8b)."""
    if a.num_fns != b.num_fns:
        raise ValueError("traces must share a function universe")
    shift = a.duration + gap
    return InvocationTrace(
        fn_id=np.concatenate([a.fn_id, b.fn_id]),
        start=np.concatenate([a.start, b.start + shift]),
        end=np.concatenate([a.end, b.end + shift]),
        num_fns=a.num_fns,
        duration=a.duration + gap + b.duration,
        fn_names=a.fn_names,
    )


def pad_trace(trace: InvocationTrace, to_multiple: int = 1024) -> InvocationTrace:
    """Pad arrays so fleets of traces share one jitted shape."""
    k = trace.fn_id.shape[0]
    rem = (-k) % to_multiple
    if rem == 0:
        return trace
    return dataclasses.replace(
        trace,
        fn_id=np.concatenate([trace.fn_id, np.full(rem, -1, np.int32)]),
        start=np.concatenate([trace.start, np.zeros(rem, np.float32)]),
        end=np.concatenate([trace.end, np.zeros(rem, np.float32)]),
    )
