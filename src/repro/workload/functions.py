"""Function registry (paper Table 2 + model-invocation functions).

A ``FunctionSpec`` carries what the *simulator* knows (true mean power draw
while running, latency distribution, resource mix) — the profiler never sees
these; it must recover them from telemetry.  The resource mix feeds the
per-source sensitivity: chip-power sensors only see ``cpu_frac`` of the
dynamic power (how the paper's `dd` breaks CPU-only profilers).

Two populations:

- ``paper_functions()``: the seven functionbench functions of Table 2, with
  the paper's desktop latencies.
- ``arch_functions()``: model-invocation classes over the assigned
  architectures (``<arch>/prefill``, ``<arch>/decode``, ``<arch>/train``),
  with power/latency derived from each arch's FLOP count — the framework's
  tenant population.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    name: str
    mean_latency_s: float
    latency_cov: float          # coefficient of variation of latency
    dyn_power_w: float          # true mean dynamic power draw while running
    cpu_frac: float = 1.0       # fraction of dyn power visible to chip sensor
    mem_gb: float = 0.5         # for GB-second pricing comparisons
    # Per-invocation step counters (TPU analogue of perf counters):
    gflops: float = 1.0
    hbm_gb: float = 0.1


class FunctionRegistry:
    """Ordered FaaS function set with stable ids and name lookup."""
    def __init__(self, specs: list[FunctionSpec]):
        if len({s.name for s in specs}) != len(specs):
            raise ValueError("duplicate function names")
        self.specs = list(specs)
        self.index = {s.name: i for i, s in enumerate(specs)}

    def __len__(self) -> int:
        return len(self.specs)

    def __getitem__(self, key: int | str) -> FunctionSpec:
        if isinstance(key, str):
            return self.specs[self.index[key]]
        return self.specs[key]

    @property
    def names(self) -> list[str]:
        return [s.name for s in self.specs]

    def without(self, name: str) -> "FunctionRegistry":
        """Registry minus one function — keeps ids stable for marginal traces
        by construction at the trace level (see trace.drop_function)."""
        return FunctionRegistry([s for s in self.specs if s.name != name])


def paper_functions() -> FunctionRegistry:
    """Table 2 functions; latencies are the paper's desktop warm latencies.

    Dynamic powers are simulator ground truth chosen to span the paper's
    observed footprint range (Fig. 3: ~5-100 J/invocation); `dd` and `json`
    are I/O-heavy (low cpu_frac) which is what defeats CPU-only profilers.
    """
    return FunctionRegistry(
        [
            FunctionSpec("dd", 0.7, 0.25, 22.0, cpu_frac=0.35, mem_gb=0.25, gflops=0.5, hbm_gb=2.0),
            FunctionSpec("image", 1.5, 0.20, 28.0, cpu_frac=0.90, mem_gb=0.5, gflops=12.0, hbm_gb=0.8),
            FunctionSpec("video", 7.8, 0.30, 35.0, cpu_frac=0.85, mem_gb=1.0, gflops=90.0, hbm_gb=6.0),
            FunctionSpec("AES", 1.4, 0.15, 30.0, cpu_frac=0.95, mem_gb=0.25, gflops=8.0, hbm_gb=0.3),
            FunctionSpec("json", 0.25, 0.20, 18.0, cpu_frac=0.60, mem_gb=0.25, gflops=0.3, hbm_gb=0.5),
            FunctionSpec("CNN", 1.3, 0.18, 40.0, cpu_frac=0.80, mem_gb=1.0, gflops=35.0, hbm_gb=1.5),
            FunctionSpec("ml_train", 5.1, 0.22, 45.0, cpu_frac=0.92, mem_gb=1.5, gflops=120.0, hbm_gb=4.0),
        ]
    )


#: TPU v5e-flavored constants used to derive invocation-class specs.
_V5E_PEAK_TFLOPS = 197.0
_V5E_DYN_W = 160.0   # dynamic chip watts at full utilization
_V5E_IDLE_W = 60.0


def arch_functions(archs: dict[str, dict] | None = None) -> FunctionRegistry:
    """Model-invocation function classes for the assigned architectures.

    ``archs`` maps arch name -> {"gflops_per_call", "latency_s", "mfu"};
    when omitted a representative default population is used (full derivation
    from configs lives in repro.configs.registry.arch_invocation_specs).
    """
    if archs is None:
        archs = {
            "internlm2-1.8b/decode": dict(gflops_per_call=3.6, latency_s=0.02, mfu=0.08),
            "granite-3-8b/prefill": dict(gflops_per_call=65536.0, latency_s=1.4, mfu=0.45),
            "olmoe-1b-7b/decode": dict(gflops_per_call=2.6, latency_s=0.015, mfu=0.05),
            "xlstm-350m/train": dict(gflops_per_call=8600.0, latency_s=0.9, mfu=0.35),
        }
    specs = []
    for name, d in archs.items():
        util = min(max(d["mfu"], 0.02), 1.0)
        specs.append(
            FunctionSpec(
                name=name,
                mean_latency_s=d["latency_s"],
                latency_cov=0.15,
                dyn_power_w=_V5E_DYN_W * util,
                cpu_frac=0.9,
                mem_gb=8.0,
                gflops=d["gflops_per_call"],
                hbm_gb=d["gflops_per_call"] / 300.0,
            )
        )
    return FunctionRegistry(specs)
