"""Fault-tolerant training loop: checkpoint/restart, watchdog, metrics.

Runnability-at-scale features exercised here (and in tests):

- **Auto-resume**: on start the trainer restores the newest *valid*
  checkpoint (crash-mid-save leaves no complete manifest, so a damaged tail
  checkpoint is skipped) and the data iterator seeks to the restored step —
  a killed job relaunches bit-identically.
- **Async checkpointing**: device->host snapshot is synchronous (cheap),
  the filesystem write overlaps the next steps.
- **Straggler watchdog**: per-step wall time vs a running median; outliers
  beyond ``watchdog_factor`` are counted and surfaced (at fleet scale this
  signal drives hot-spare swap / requeue — here it feeds metrics and tests).
- **Elastic re-shard**: restore works onto any mesh via the sharding tree
  (see ``distributed.checkpoint``); changing mesh shape between runs is a
  config change, not a migration.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.distributed.checkpoint import CheckpointManager
from repro.training.train_step import TrainState


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    log_every: int = 10
    watchdog_factor: float = 3.0   # step > factor x median => straggler
    watchdog_warmup: int = 5       # ignore first steps (compile)


@dataclasses.dataclass
class TrainerReport:
    steps_run: int = 0
    resumed_from: int | None = None
    straggler_steps: int = 0
    losses: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


class Trainer:
    """Step-loop driver: data iterator, checkpoint/resume, straggler watchdog."""
    def __init__(
        self,
        step_fn: Callable[[TrainState, dict], tuple[TrainState, dict]],
        init_state: TrainState,
        data_iter_factory: Callable[[int], Iterator[dict]],
        config: TrainerConfig = TrainerConfig(),
        *,
        state_shardings: Any = None,
        on_step: Callable[[int, dict], None] | None = None,
    ):
        self.step_fn = step_fn
        self.state = init_state
        self.data_iter_factory = data_iter_factory
        self.config = config
        self.on_step = on_step
        self._shardings = state_shardings
        self.ckpt = (
            CheckpointManager(config.checkpoint_dir, keep=config.keep_checkpoints)
            if config.checkpoint_dir
            else None
        )

    def run(self) -> TrainerReport:
        cfg = self.config
        report = TrainerReport()
        start = 0
        if self.ckpt is not None:
            restored = self.ckpt.restore_latest(self.state, shardings=self._shardings)
            if restored is not None:
                start, self.state = restored
                report.resumed_from = start
        data = self.data_iter_factory(start)

        times: list[float] = []
        for step in range(start, cfg.total_steps):
            batch = next(data)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(jax.device_get(metrics["loss"]))
            dt = time.perf_counter() - t0
            report.steps_run += 1
            report.losses.append(loss)
            report.step_times.append(dt)

            # Straggler watchdog.
            if len(times) >= cfg.watchdog_warmup:
                med = float(np.median(times[-50:]))
                if dt > cfg.watchdog_factor * med:
                    report.straggler_steps += 1
            times.append(dt)

            if self.on_step is not None:
                self.on_step(step, {**metrics, "step_time_s": dt})
            if self.ckpt is not None and (step + 1) % cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, self.state)
        if self.ckpt is not None:
            self.ckpt.save(cfg.total_steps, self.state, blocking=True)
            self.ckpt.wait()
        return report
