"""Optimizer: AdamW with global-norm clipping and warmup-cosine schedule,
plus an int8 error-feedback gradient compressor for the cross-pod axis.

Self-contained (no optax on the target hosts); the state is a pytree of the
same structure as the params, so it inherits the params' shardings leaf for
leaf — optimizer state is FSDP-sharded exactly like the weights.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # int8 error-feedback gradient compression across the "pod" axis.
    compress_grads: bool = False


class AdamState(NamedTuple):
    mu: Any        # first moment, same tree as params
    nu: Any        # second moment
    count: Array   # scalar int32 step
    err: Any       # error-feedback residuals (zeros tree when compression off)


def init(params: Any, config: OptimizerConfig) -> AdamState:
    """Zero-initialized Adam state (plus error buffer when compressing grads)."""
    zeros = jax.tree.map(jnp.zeros_like, params)
    err = jax.tree.map(jnp.zeros_like, params) if config.compress_grads else None
    return AdamState(mu=zeros, nu=jax.tree.map(jnp.zeros_like, params),
                     count=jnp.zeros((), jnp.int32), err=err)


def schedule(step: Array, config: OptimizerConfig) -> Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(config.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - config.warmup_steps)
        / jnp.maximum(config.total_steps - config.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    decay = config.min_lr_ratio + (1.0 - config.min_lr_ratio) * cos
    return config.learning_rate * warm * decay


def global_norm(tree: Any) -> Array:
    """Global L2 norm over a gradient tree (float32 accumulation)."""
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    """Scale grads onto the ``max_norm`` ball; returns (clipped, pre-clip norm)."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------


def quantize_int8(x: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    """Reconstruct float32 values from an int8 payload and its scale."""
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Any, err: Any) -> tuple[Any, Any]:
    """Error-feedback int8 round trip: g' = deq(quant(g + err)).

    The residual (g + err) - g' is carried to the next step, so the
    compression is unbiased over time (Karimireddy et al. style EF-SGD).
    On a real pod this wraps the cross-pod all-reduce (the int8 payload is
    what crosses DCN); ``distributed.collectives.compressed_psum`` is the
    shard_map collective form.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), (target - deq).astype(e.dtype)

    pairs = jax.tree.map(one, grads, err)
    new_grads = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err


# ---------------------------------------------------------------------------
# AdamW update
# ---------------------------------------------------------------------------


def update(
    grads: Any, state: AdamState, params: Any, config: OptimizerConfig
) -> tuple[Any, AdamState, dict[str, Array]]:
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    new_err = state.err
    if config.compress_grads and state.err is not None:
        grads, new_err = ef_compress(grads, state.err)

    grads, grad_norm = clip_by_global_norm(grads, config.clip_norm)
    count = state.count + 1
    lr = schedule(count.astype(jnp.float32), config)
    b1, b2 = config.beta1, config.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def leaf(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * g32 * g32
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        step_ = m_hat / (jnp.sqrt(v_hat) + config.eps)
        p_new = p.astype(jnp.float32) - lr * (step_ + config.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(leaf, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = AdamState(mu=new_mu, nu=new_nu, count=count, err=new_err)
    return new_params, new_state, {"grad_norm": grad_norm, "lr": lr}
