"""Training runtime: optimizer, train step, fault-tolerant trainer."""
