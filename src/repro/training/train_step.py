"""Jitted train step: loss + grad + AdamW, with mesh-aware shardings.

``make_train_step`` closes over the ModelApi and optimizer config and
returns the pure (state, batch) -> (state, metrics) function; the launchers
jit it with in/out shardings derived from the params' logical axes (and the
dry-run lowers it against ShapeDtypeStructs without allocating anything).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.common import abstract, logical_axes, materialize
from repro.models.model_zoo import ModelApi, spec_abstract, spec_logical
from repro.training import optimizer as opt

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamState


def init_state(api: ModelApi, rng: jax.Array, config: opt.OptimizerConfig) -> TrainState:
    """Materialize params + optimizer state from the ModelApi spec tree."""
    params = materialize(api.params_def, rng)
    return TrainState(params=params, opt=opt.init(params, config))


def abstract_state(api: ModelApi, config: opt.OptimizerConfig) -> TrainState:
    """ShapeDtypeStruct twin of the train state (dry-run: no allocation)."""
    params = abstract(api.params_def, jnp.float32)
    zeros = params
    err = params if config.compress_grads else None
    return TrainState(
        params=params,
        opt=opt.AdamState(mu=zeros, nu=zeros, count=jax.ShapeDtypeStruct((), jnp.int32), err=err),
    )


def state_logical(api: ModelApi, config: opt.OptimizerConfig) -> TrainState:
    """Logical-axis tree matching ``TrainState`` (moments mirror params)."""
    axes = logical_axes(api.params_def)
    err = axes if config.compress_grads else None
    return TrainState(
        params=axes,
        opt=opt.AdamState(mu=axes, nu=axes, count=(), err=err),
    )


def state_shardings(api: ModelApi, config: opt.OptimizerConfig, mesh, rules) -> TrainState:
    """NamedSharding tree for the train state under (mesh, rules)."""
    log = state_logical(api, config)
    abs_ = abstract_state(api, config)
    return jax.tree.map(
        lambda ax, a: shd.sharding_for(ax, a.shape, mesh, rules),
        log, abs_,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def batch_shardings(spec_tree: Any, mesh, rules) -> Any:
    """NamedSharding tree for a host-batch spec tree."""
    return jax.tree.map(
        lambda s: shd.sharding_for(s.axes, s.shape, mesh, rules),
        spec_tree,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "dtype"),
    )


def abstract_batch(spec_tree: Any) -> Any:
    """ShapeDtypeStruct tree for a host-batch spec tree."""
    return spec_abstract(spec_tree)


def make_train_step(
    api: ModelApi, config: opt.OptimizerConfig, *, accum_steps: int = 1,
    cast_params: bool = False,
):
    """(state, batch) -> (state, metrics).  Pure; jit at the call site.

    ``accum_steps > 1`` splits the global batch into microbatches and scans
    gradient accumulation over them — activation memory (saved carries,
    logits buffers) scales down by the accumulation factor while the math is
    identical (mean of microbatch grads == full-batch grad for mean losses).

    ``cast_params`` casts the fp32 master weights to the model's compute
    dtype ONCE, outside the layer scan — so every FSDP all-gather moves
    bf16, not fp32, halving per-layer weight-gather bytes (§Perf H-A1).
    Gradients flow through the cast and land in fp32 on the master tree.
    """
    compute_dtype = jnp.dtype(api.cfg.compute_dtype)

    def loss_fn(params, mb):
        if cast_params:
            params = jax.tree.map(
                lambda p: p.astype(compute_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2
                else p,
                params,
            )
        return api.loss(params, mb)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if accum_steps <= 1:

        def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
            (loss, metrics), grads = grad_fn(state.params, batch)
            new_params, new_opt, stats = opt.update(grads, state.opt, state.params, config)
            metrics = {**metrics, **stats, "loss": loss}
            return TrainState(params=new_params, opt=new_opt), metrics

        return train_step

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:]),
            batch,
        )
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(state.params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, loss

        grads, losses = jax.lax.scan(body, zero_grads, micro)
        grads = jax.tree.map(lambda g: g / accum_steps, grads)
        loss = jnp.mean(losses)
        new_params, new_opt, stats = opt.update(grads, state.opt, state.params, config)
        metrics = {"loss": loss, **stats}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def jit_train_step(api: ModelApi, config: opt.OptimizerConfig, mesh, rules):
    """Fully-sharded jitted train step + the sharding trees used to build it."""
    step = make_train_step(api, config)
    st_sh = state_shardings(api, config, mesh, rules)
    train_spec = None  # resolved per shape by the caller

    def compile_for(shape):
        specs = api.train_inputs(shape)
        b_sh = batch_shardings(specs, mesh, rules)
        jitted = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        return jitted, specs

    del train_spec
    return compile_for, st_sh
