"""Pure-jnp reference oracles for every Pallas kernel.

These are the *semantics* of the kernels — used (a) as the CPU/dry-run
execution path (memory-sane: blocked online-softmax with a hand-written
FlashAttention backward, never materializing S x S score matrices), and
(b) as the ground truth that ``tests/test_kernels.py`` sweeps the Pallas
kernels against in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def _gqa_expand(q: Array, num_kv: int) -> Array:
    """(B, S, H, d) -> (B, S, Hkv, G, d)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def attention_dense(
    q: Array, k: Array, v: Array, *, causal: bool = True, scale: float | None = None
) -> Array:
    """Unblocked GQA attention — the simplest possible oracle (small shapes
    only; materializes the score matrix)."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qg = _gqa_expand(q, hkv).astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32)) * scale
    if causal:
        t = k.shape[1]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blocked flash attention with a hand-written (recomputing) backward pass.
# ---------------------------------------------------------------------------


def _flash_fwd_inner(qg, kb, vb, qi, *, causal, offset, scale, q_block, kv_block, nk):
    """Online-softmax pass of one q block over its kv blocks.

    qg: (B, qb, Hkv, G, d); kb/vb: (B, nk, kvb, Hkv, d).
    Returns out (B, qb, Hkv, G, d) fp32 and lse (B, Hkv, G, qb).
    """
    b, qb, hkv, g, d = qg.shape
    q32 = qg.astype(jnp.float32)

    def kv_step(ki, carry):
        m, l, acc = carry
        kk = kb[:, ki].astype(jnp.float32)
        vv = vb[:, ki].astype(jnp.float32)
        scores = jnp.einsum("bqkgd,btkd->bkgqt", q32, kk) * scale
        if causal:
            q_pos = qi * q_block + jnp.arange(q_block) + offset
            k_pos = ki * kv_block + jnp.arange(kv_block)
            mask = q_pos[:, None] >= k_pos[None, :]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqt,btkd->bkgqd", p, vv)
        return m_new, l_new, acc * corr[..., None] + pv

    m0 = jnp.full((b, hkv, g, qb), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, qb), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, qb, d), jnp.float32)
    if causal:
        hi = jnp.minimum((qi * q_block + q_block + offset + kv_block - 1) // kv_block, nk)
    else:
        hi = nk
    m, l, acc = jax.lax.fori_loop(0, hi, kv_step, (m0, l0, a0))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4), lse  # (B, qb, Hkv, G, d), (B, Hkv, G, qb)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: Array,               # (B, S, H, d)
    k: Array,               # (B, T, Hkv, d)
    v: Array,               # (B, T, Hkv, d)
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
) -> Array:
    """Blocked online-softmax GQA attention (FlashAttention semantics).

    Memory is O(q_block x kv_block) per head regardless of S, in both the
    forward and the hand-written recomputing backward — so the HLO the
    dry-run lowers has an honest memory profile for training too.
    """
    out, _ = _flash_fwd(q, k, v, causal, q_block, kv_block)
    return out


def _flash_fwd(q, k, v, causal, q_block, kv_block):
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q_block = min(q_block, s)
    kv_block = min(kv_block, t)
    assert s % q_block == 0 and t % kv_block == 0, (s, q_block, t, kv_block)
    nq, nk = s // q_block, t // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    offset = t - s

    qg = q.reshape(b, nq, q_block, hkv, g, d)
    kb = k.reshape(b, nk, kv_block, hkv, d)
    vb = v.reshape(b, nk, kv_block, hkv, d)

    def per_q(i):
        return _flash_fwd_inner(
            qg[:, i], kb, vb, i,
            causal=causal, offset=offset, scale=scale,
            q_block=q_block, kv_block=kv_block, nk=nk,
        )

    outs, lses = jax.lax.map(per_q, jnp.arange(nq))
    # outs: (nq, B, qb, Hkv, G, d) -> (B, S, H, d)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, d).astype(q.dtype)
    # lses: (nq, B, Hkv, G, qb) -> (B, Hkv, G, S)
    lse = jnp.moveaxis(lses, 0, -2).reshape(b, hkv, g, s)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_block, kv_block, res, dout):
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q_block = min(q_block, s)
    kv_block = min(kv_block, t)
    nq, nk = s // q_block, t // kv_block
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    offset = t - s

    qg = q.reshape(b, nq, q_block, hkv, g, d)
    og = out.reshape(b, nq, q_block, hkv, g, d)
    dog = dout.reshape(b, nq, q_block, hkv, g, d)
    lseg = lse.reshape(b, hkv, g, nq, q_block)
    kb = k.reshape(b, nk, kv_block, hkv, d)
    vb = v.reshape(b, nk, kv_block, hkv, d)
    # D = rowsum(dO * O): (B, nq, qb, Hkv, G)
    dsum = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    def _p_block(qi, ki):
        """Recompute the (masked, normalized) probability block."""
        q32 = qg[:, qi].astype(jnp.float32)
        kk = kb[:, ki].astype(jnp.float32)
        scores = jnp.einsum("bqkgd,btkd->bkgqt", q32, kk) * scale
        if causal:
            q_pos = qi * q_block + jnp.arange(q_block) + offset
            k_pos = ki * kv_block + jnp.arange(kv_block)
            mask = q_pos[:, None] >= k_pos[None, :]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        return jnp.exp(scores - lseg[:, :, :, qi][..., None])  # (B,Hkv,G,qb,kvb)

    def _ds_block(qi, ki, p):
        do32 = dog[:, qi].astype(jnp.float32)
        vv = vb[:, ki].astype(jnp.float32)
        dp = jnp.einsum("bqkgd,btkd->bkgqt", do32, vv)
        return p * (dp - dsum[:, qi].transpose(0, 2, 3, 1)[..., None])

    # dq: loop over q blocks, accumulate over this block's kv range.
    def dq_step(qi):
        def inner(ki, acc):
            p = _p_block(qi, ki)
            ds = _ds_block(qi, ki, p)
            kk = kb[:, ki].astype(jnp.float32)
            return acc + jnp.einsum("bkgqt,btkd->bqkgd", ds, kk) * scale

        hi = (
            jnp.minimum((qi * q_block + q_block + offset + kv_block - 1) // kv_block, nk)
            if causal
            else nk
        )
        acc0 = jnp.zeros((b, q_block, hkv, g, d), jnp.float32)
        return jax.lax.fori_loop(0, hi, inner, acc0)

    dq = jax.lax.map(dq_step, jnp.arange(nq))          # (nq, B, qb, Hkv, G, d)
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, s, h, d).astype(q.dtype)

    # dk/dv: loop over kv blocks, accumulate over contributing q blocks.
    def dkv_step(ki):
        def inner(qi, carry):
            dk_acc, dv_acc = carry
            p = _p_block(qi, ki)
            ds = _ds_block(qi, ki, p)
            q32 = qg[:, qi].astype(jnp.float32)
            do32 = dog[:, qi].astype(jnp.float32)
            dk_acc = dk_acc + jnp.einsum("bkgqt,bqkgd->btkd", ds, q32) * scale
            dv_acc = dv_acc + jnp.einsum("bkgqt,bqkgd->btkd", p, do32)
            return dk_acc, dv_acc

        lo = (
            jnp.maximum((ki * kv_block - offset) // q_block, 0) if causal else 0
        )
        z = jnp.zeros((b, kv_block, hkv, d), jnp.float32)
        dk_b, dv_b = jax.lax.fori_loop(lo, nq, inner, (z, z))
        return dk_b, dv_b

    dks, dvs = jax.lax.map(dkv_step, jnp.arange(nk))   # (nk, B, kvb, Hkv, d)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, t, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, t, hkv, d).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=())
def decode_attention(
    q: Array,            # (B, H, d) single query token per sequence
    k_cache: Array,      # (B, S, Hkv, d)
    v_cache: Array,      # (B, S, Hkv, d)
    lengths: Array,      # (B,) valid KV length per sequence
) -> Array:
    """Single-token GQA attention against a (possibly partially filled) KV
    cache; masked beyond ``lengths``.  Returns (B, H, d)."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=())
def decode_attention_quant(
    q: Array,            # (B, H, d)
    k_cache: Array,      # (B, S, Hkv, d) int8
    v_cache: Array,      # (B, S, Hkv, d) int8
    k_scale: Array,      # (B, S, Hkv) f32/bf16 per-row scales
    v_scale: Array,
    lengths: Array,      # (B,)
) -> Array:
    """Decode attention over an int8-quantized KV cache.

    Dequantization is folded around the contractions so the int8 tensors are
    never materialized at higher precision:  scores = (q . k_q) * k_scale,
    and  out = (p * v_scale) . v_q  — HBM reads stay at 1 byte/element,
    which is the whole point (decode is KV-bandwidth-bound).
    """
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32))
    scores = scores * k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :] * scale
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    pv = p * v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bkgt,btkd->bkgd", pv, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def quantize_kv(x: Array) -> tuple[Array, Array]:
    """Per-(position, head) symmetric int8 quantization of K or V rows.

    x: (B, S, Hkv, d) -> (int8 same shape, scales (B, S, Hkv))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def disagg_gram(c: Array, w: Array) -> tuple[Array, Array]:
    """Normal-equation assembly for the disaggregation solve (paper Eq. 1).

    Args:
      c: (..., N, M) contribution windows; w: (..., N) power targets.
    Returns:
      gram (..., M, M) = C^T C and rhs (..., M) = C^T W in fp32.
    """
    c32 = c.astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    gram = jnp.einsum("...nm,...nk->...mk", c32, c32)
    rhs = jnp.einsum("...nm,...n->...m", c32, w32)
    return gram, rhs


def rmsnorm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    """Reference for the fused RMSNorm kernel."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)
