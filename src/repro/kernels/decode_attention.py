"""Pallas TPU decode attention: one query token vs a long KV cache.

The 32k/500k decode cells are memory-bound: the step reads the whole KV
cache once at ~O(1) compute per byte.  The kernel streams KV blocks through
VMEM with the online-softmax carried in scratch — grid (batch, kv_head,
kv_blocks), the group's G query heads processed together so each staged KV
block is reused G times (GQA's arithmetic-intensity advantage made
explicit).  ``lengths`` masks the unfilled cache tail.

Tiles: (kv_block x d) K and V in VMEM (+ the (G x d) query tile); default
kv_block=2048, d=128 => 2 MB staged per step, double-buffered by the
pipeline.  Validated against ``ref.decode_attention`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _dec_kernel(
    len_ref,                    # scalar-prefetch: (B,) lengths
    q_ref, k_ref, v_ref,        # (1, G, d), (1, kvb, 1, d) x2
    o_ref,                      # (1, G, d)
    acc_ref, m_ref, l_ref,      # scratch: (G, d), (G,), (G,)
    *, kv_block: int, nk: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    live = ki * kv_block < length

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # (G, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (kvb, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (1.0 / (d ** 0.5))                               # (G, kvb)
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kv_block", "interpret"))
def decode_attention(
    q: jax.Array,         # (B, H, d)
    k_cache: jax.Array,   # (B, S, Hkv, d)
    v_cache: jax.Array,
    lengths: jax.Array,   # (B,) int32 valid KV length
    *,
    kv_block: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Single-query decode attention over a KV cache (Pallas, KV-blocked)."""
    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    kv_block = min(kv_block, max(s, 8))
    rem = (-s) % kv_block
    if rem:
        pad = [(0, 0)] * 4
        pad[1] = (0, rem)
        k_cache = jnp.pad(k_cache, pad)
        v_cache = jnp.pad(v_cache, pad)
    sp = k_cache.shape[1]
    nk = sp // kv_block
    # (B, H, d) -> (B, Hkv, G, d) so one program handles one kv head's group.
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_dec_kernel, kv_block=kv_block, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, ki, lens: (b_, h_, 0, 0)),
            pl.BlockSpec((1, kv_block, 1, d), lambda b_, h_, ki, lens: (b_, ki, h_, 0)),
            pl.BlockSpec((1, kv_block, 1, d), lambda b_, h_, ki, lens: (b_, ki, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, ki, lens: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, d)
