"""Pallas TPU flash attention (forward): blocked online-softmax GQA.

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv dimension
"arbitrary" (sequential) — the standard TPU flash schedule: VMEM scratch
carries (acc, m, l) across kv blocks, initialized at the first kv block and
finalized (acc / l) at the last.  Causal block-skipping: fully-masked
(q_block, kv_block) pairs skip their compute via ``pl.when``.

BlockSpecs stage one (q_block x head_dim) query tile and one
(kv_block x head_dim) K/V tile in VMEM per program — working set
``q_block*d + 2*kv_block*d + q_block*kv_block`` fp32 words; the default
(512, 1024) tiles with d=128 stay under ~3.5 MB, comfortably inside the
~16 MB v5e VMEM alongside double-buffering.  MXU alignment: tiles are
multiples of (128, 128); the wrapper pads S/T up and slices the output.

Training uses the recomputing custom-VJP in ``ref.py`` (same blocked
semantics); this kernel is the serving/prefill forward hot path.  Validated
against ``ref.flash_attention`` in interpret mode over shape/dtype sweeps
(tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,       # VMEM tiles
    o_ref,                     # output tile
    acc_ref, m_ref, l_ref,     # VMEM scratch carried over kv blocks
    *, causal: bool, scale: float, q_block: int, kv_block: int,
    nk: int, offset: int, kv_len: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal block skip: last q position < first k position => fully masked.
    q_last = qi * q_block + q_block - 1 + offset
    k_first = ki * kv_block
    live = (q_last >= k_first) if causal else (k_first < kv_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (qb, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (kvb, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                           # (qb, kvb)
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            q_pos = qi * q_block + offset + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0
            )
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        # Mask padded keys (kv padded up to a block multiple).
        s = jnp.where(k_pos < kv_len, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _pad_to(x, axis: int, mult: int):
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret")
)
def flash_attention(
    q: jax.Array,               # (B, S, H, d)
    k: jax.Array,               # (B, T, Hkv, d)
    v: jax.Array,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Blocked online-softmax attention (Pallas); matches ``ref.attention_ref``."""
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    q_block = min(q_block, max(s, 8))
    kv_block = min(kv_block, max(t, 8))
    offset = t - s

    qp = _pad_to(q, 1, q_block)
    kp = _pad_to(k, 1, kv_block)
    vp = _pad_to(v, 1, kv_block)
    sp, tp = qp.shape[1], kp.shape[1]
    nq, nk = sp // q_block, tp // kv_block
    scale = float(1.0 / (d ** 0.5))

    kernel = functools.partial(
        _fa_kernel,
        causal=causal, scale=scale, q_block=q_block, kv_block=kv_block,
        nk=nk, offset=offset, kv_len=t,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, kv_block, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // group, 0)),
            pl.BlockSpec((1, kv_block, 1, d), lambda b_, h_, qi, ki: (b_, ki, h_ // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, d), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, d), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s]
