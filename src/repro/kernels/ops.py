"""Jitted public entry points for the compute hot-spots.

Dispatch policy: on TPU backends the Pallas kernels run (explicit BlockSpec
VMEM tiling, MXU-aligned); on CPU — including the 512-fake-device dry-run —
the pure-jnp references in ``ref.py`` execute, which share blocked structure
(and therefore an honest memory profile) with the kernels.  Set
``REPRO_FORCE_KERNELS=interpret`` to route through the Pallas kernels in
interpret mode (used by the kernel test sweeps).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref

Array = jax.Array


def _kernel_mode() -> str:
    forced = os.environ.get("REPRO_FORCE_KERNELS", "")
    if forced:
        return forced  # "interpret" | "pallas" | "ref"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
) -> Array:
    """Blocked GQA attention (B, S, H, d) x (B, T, Hkv, d) -> (B, S, H, d)."""
    mode = _kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fa

        return fa.flash_attention(
            q, k, v, causal=causal, q_block=q_block, kv_block=kv_block,
            interpret=(mode == "interpret"),
        )
    return ref.flash_attention(q, k, v, causal, q_block, kv_block)


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, lengths: Array, *, kv_block: int = 2048
) -> Array:
    """Single-token GQA attention against a KV cache: (B, H, d)."""
    mode = _kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import decode_attention as da

        return da.decode_attention(
            q, k_cache, v_cache, lengths, kv_block=kv_block,
            interpret=(mode == "interpret"),
        )
    return ref.decode_attention(q, k_cache, v_cache, lengths)


@functools.partial(jax.jit, static_argnames=())
def disagg_gram(c: Array, w: Array) -> tuple[Array, Array]:
    """Batched normal-equation assembly (C^T C, C^T W) for the fleet solve."""
    mode = _kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import disagg_solve as ds

        return ds.disagg_gram(c, w, interpret=(mode == "interpret"))
    return ref.disagg_gram(c, w)


def rmsnorm(x: Array, gamma: Array, eps: float = 1e-5) -> Array:
    """Fused RMSNorm (TPU) / jnp reference (CPU)."""
    mode = _kernel_mode()
    if mode in ("pallas", "interpret"):
        from repro.kernels import rmsnorm as rn

        return rn.rmsnorm(x, gamma, eps=eps, interpret=(mode == "interpret"))
    return ref.rmsnorm(x, gamma, eps)
