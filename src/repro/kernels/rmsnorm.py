"""Pallas TPU fused RMSNorm: one pass, fp32 accumulation, row-blocked.

Unfused, RMSNorm reads x twice (square-reduce, then scale) and round-trips
an fp32 intermediate through HBM.  The kernel stages a (rows x d) tile in
VMEM, computes the row rsqrt statistics and writes the scaled tile once —
bandwidth 2x better, which matters on the decode path where every block is
memory-bound.  Validated against ``ref.rmsnorm`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _rms_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "row_block", "interpret"))
def rmsnorm(
    x: jax.Array,          # (..., d)
    gamma: jax.Array,      # (d,)
    *,
    eps: float = 1e-5,
    row_block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Row-blocked Pallas RMSNorm over the last axis (matches ``ref.rmsnorm_ref``)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    rb = min(row_block, max(rows, 8))
    rem = (-rows) % rb
    if rem:
        xf = jnp.pad(xf, [(0, rem), (0, 0)])
    nr = xf.shape[0] // rb

    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(xf, gamma)
    return out[:rows].reshape(orig_shape)
