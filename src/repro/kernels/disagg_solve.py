"""Pallas TPU kernel for the paper's core computation at fleet scale:
batched normal-equation assembly for the disaggregation solve (Eq. 1).

The paper solves ``min_X ||C X - W||`` per server with scipy on the host.
A fleet controller solves it for (nodes x Kalman-windows) batches each
step.  TPU-native rethink: assemble ``G = C^T C`` (M x M) and ``r = C^T W``
(M) for the whole batch in one MXU-tiled pass — the window dimension N
(thousands) is the contraction dim, streamed through VMEM in ``n_block``
tiles and accumulated in an f32 VMEM scratch; M (functions per node, 64-256)
is MXU-aligned by padding.  The small SPD solves then run as a batched
Cholesky on the assembled grams (they are O(M^3) with tiny constants — the
bandwidth-heavy part is this assembly, which is what the kernel owns).

Grid: (batch, n_blocks); n_blocks is the sequential axis carrying the
accumulator.  Validated against ``ref.disagg_gram`` in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _gram_kernel(c_ref, w_ref, g_ref, r_ref, acc_g, acc_r, *, nn: int):
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_r[...] = jnp.zeros_like(acc_r)

    c = c_ref[0].astype(jnp.float32)                        # (nb, M)
    w = w_ref[...].astype(jnp.float32)                      # (1, nb)
    acc_g[...] += jax.lax.dot_general(
        c, c, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_r[...] += jax.lax.dot_general(
        w, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ni == nn - 1)
    def _finalize():
        g_ref[0] = acc_g[...].astype(g_ref.dtype)
        r_ref[0] = acc_r[...].astype(r_ref.dtype)


def _pad_axis(x, axis, mult):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("n_block", "interpret"))
def disagg_gram(
    c: jax.Array,     # (G, N, M) contribution windows (zero rows are inert)
    w: jax.Array,     # (G, N) power targets
    *,
    n_block: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (gram (G, M, M), rhs (G, M)) in fp32."""
    squeeze = False
    if c.ndim == 2:
        c, w, squeeze = c[None], w[None], True
    g_b, n, m = c.shape
    n_block = min(n_block, max(n, 8))
    # Pad M to the 128-lane MXU width and N to the block size; zero padding
    # contributes nothing to either product.
    m_pad = max(((m + 127) // 128) * 128, 128)
    cp = jnp.pad(c, [(0, 0), (0, (-n) % n_block), (0, m_pad - m)])
    wp = _pad_axis(w, 1, n_block)
    nn = cp.shape[1] // n_block

    kernel = functools.partial(_gram_kernel, nn=nn)
    gram, rhs = pl.pallas_call(
        kernel,
        grid=(g_b, nn),
        in_specs=[
            pl.BlockSpec((1, n_block, m_pad), lambda b, ni: (b, ni, 0)),
            pl.BlockSpec((1, n_block), lambda b, ni: (b, ni)),
        ],
        out_specs=[
            pl.BlockSpec((1, m_pad, m_pad), lambda b, ni: (b, 0, 0)),
            pl.BlockSpec((1, 1, m_pad), lambda b, ni: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g_b, m_pad, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((g_b, 1, m_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((m_pad, m_pad), jnp.float32),
            pltpu.VMEM((1, m_pad), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(cp, wp)
    gram = gram[:, :m, :m]
    rhs = rhs[:, 0, :m]
    if squeeze:
        return gram[0], rhs[0]
    return gram, rhs


def default_backend() -> str:
    """Gram-assembly backend for the batched engine: the Pallas kernel owns
    the contraction on TPU; elsewhere a plain XLA einsum is both faster and
    exact (interpret-mode Pallas runs at Python speed)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def disagg_solve_nnls(
    c: jax.Array, w: jax.Array, lam: float = 1e-3,
    *, iters: int = 200, interpret: bool = False,
) -> jax.Array:
    """Kernel-assembled NNLS: Pallas gram pass + batched gram-domain FISTA.

    The fleet engine's per-tick solve: (G, N, M) contribution batches in,
    (G, M) non-negative power estimates out, with the window dimension
    touched exactly once (inside the kernel).
    """
    from repro.core.disaggregation import solve_nnls_gram

    gram, rhs = disagg_gram(c, w, interpret=interpret)
    m = gram.shape[-1]
    gram = gram + lam * jnp.eye(m, dtype=gram.dtype)
    return solve_nnls_gram(gram, rhs, iters=iters)


@functools.partial(jax.jit, static_argnames=("interpret", "nonneg"))
def disagg_solve(
    c: jax.Array, w: jax.Array, lam: float = 1e-3,
    *, nonneg: bool = True, interpret: bool = False,
) -> jax.Array:
    """Kernel-assembled ridge solve: Cholesky on the (G, M, M) grams."""
    gram, rhs = disagg_gram(c, w, interpret=interpret)
    m = gram.shape[-1]
    gram = gram + lam * jnp.eye(m, dtype=gram.dtype)
    chol = jnp.linalg.cholesky(gram)
    x = jax.scipy.linalg.cho_solve((chol, True), rhs[..., None])[..., 0]
    return jnp.maximum(x, 0.0) if nonneg else x
