"""Pallas-TPU API shims.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` upstream;
kernel modules import the name from here so they run on either JAX.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
