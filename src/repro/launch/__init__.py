"""Launchers: meshes, multi-pod dry-run, roofline, train/serve drivers."""
