"""Training launcher: ``--arch <id> [--reduced] --steps N``.

On this CPU container the reduced configs train for real (the quickstart /
fault-tolerance path); on a TPU pod the same launcher takes the full config
and the production mesh.  XLA latency-hiding / async-collective flags are
enabled for TPU backends.

Example::

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import os


def _tpu_xla_flags() -> None:
    if os.environ.get("REPRO_TPU_FLAGS"):
        os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + (
            " --xla_tpu_enable_latency_hiding_scheduler=true"
            " --xla_tpu_enable_async_collective_fusion=true"
            " --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
            " --xla_tpu_overlap_compute_collective_tc=true"
        )


_tpu_xla_flags()

import jax  # noqa: E402

from repro.configs.registry import get_config  # noqa: E402
from repro.configs.shapes import ShapeConfig  # noqa: E402
from repro.data.pipeline import DataConfig, batch_iterator  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_local_mesh, make_production_mesh  # noqa: E402
from repro.models import build  # noqa: E402
from repro.training import optimizer as opt  # noqa: E402
from repro.training.train_step import (  # noqa: E402
    batch_shardings,
    init_state,
    make_train_step,
    state_shardings,
)
from repro.training.trainer import Trainer, TrainerConfig  # noqa: E402


def main() -> None:
    """CLI: short training run for one architecture cell."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mesh", choices=["local", "pod", "multipod"], default="local")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    api = build(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    ocfg = opt.OptimizerConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        compress_grads=args.compress_grads,
    )
    mesh = {
        "local": make_local_mesh,
        "pod": lambda: make_production_mesh(multi_pod=False),
        "multipod": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()
    rules = shd.TRAIN_RULES

    st_sh = state_shardings(api, ocfg, mesh, rules)
    b_sh = batch_shardings(api.train_inputs(shape), mesh, rules)
    with shd.use_rules(mesh, rules):
        step = jax.jit(
            make_train_step(api, ocfg, accum_steps=args.accum),
            in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        state = init_state(api, jax.random.PRNGKey(args.seed), ocfg)
        state = jax.device_put(state, st_sh)

        def data_factory(start_step: int):
            return batch_iterator(
                api, shape, DataConfig(seed=args.seed),
                start_step=start_step, shardings=b_sh,
            )

        trainer = Trainer(
            lambda s, b: step(s, b),
            state,
            data_factory,
            TrainerConfig(
                total_steps=args.steps,
                checkpoint_every=args.ckpt_every,
                checkpoint_dir=args.ckpt_dir,
                log_every=10,
            ),
            state_shardings=st_sh,
            on_step=lambda i, m: print(
                f"step {i:5d} loss={float(m['loss']):.4f} "
                f"lr={float(m.get('lr', 0)):.2e} t={m['step_time_s']:.3f}s",
                flush=True,
            ) if i % 10 == 0 else None,
        )
        report = trainer.run()
    print(
        f"done: {report.steps_run} steps, final loss {report.final_loss:.4f}, "
        f"resumed_from={report.resumed_from}, stragglers={report.straggler_steps}"
    )


if __name__ == "__main__":
    main()
