"""Analytic per-step cost model: FLOPs, HBM bytes, collective bytes.

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE (we
measured a 10-iteration scan reporting 1x body flops), so scan-over-layers
programs under-report by ~the layer count.  The roofline therefore uses
this cost model for the compute and memory terms, and the structured HLO
parse (``collectives.collective_bytes_structured``: body-bucket x layer
count) for the collective term.  The model is validated two ways in tests:
(a) dense-family forward flops within 10 % of the 2*N*D convention, and
(b) against ``cost_analysis()`` on tiny UNROLLED (loop-free) models.

All quantities are GLOBAL per step; roofline terms divide by (chips x
per-chip peak).  T below = tokens processed by the step.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig

FP32 = 4
BF16 = 2


@dataclasses.dataclass(frozen=True)
class StepCost:
    flops: float              # global FLOPs per step
    hbm_bytes: float          # global HBM traffic per step
    details: dict

    def per_device(self, n: int) -> "StepCost":
        return StepCost(self.flops / n, self.hbm_bytes / n, self.details)


# ---------------------------------------------------------------------------
# Forward FLOPs per family (per token unless noted)
# ---------------------------------------------------------------------------


def _attn_proj_flops(cfg: ArchConfig) -> float:
    """QKV + output projections, per token."""
    d = cfg.d_model
    return 2.0 * d * (cfg.q_dim + 2 * cfg.kv_dim) + 2.0 * cfg.q_dim * d


def _attn_score_flops(cfg: ArchConfig, t_q: float, kv_len: float, causal: bool) -> float:
    """Score + PV contractions, TOTAL over t_q query tokens."""
    factor = 0.5 if causal else 1.0  # causal averages kv_len/2 per query
    return 2.0 * 2.0 * t_q * kv_len * factor * cfg.q_dim


def _mlp_flops(cfg: ArchConfig, d_ff: int | None = None) -> float:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    nmat = 3 if cfg.mlp == "swiglu" else 2
    return 2.0 * nmat * d * f


def _moe_flops(cfg: ArchConfig) -> float:
    """Router + shared + active routed experts, per token."""
    d = cfg.d_model
    router = 2.0 * d * cfg.num_experts
    active = 2.0 * 3 * d * cfg.expert_d_ff * (cfg.top_k + cfg.num_shared_experts)
    # Capacity slack: buffers are sized capacity_factor x the mean load, and
    # the dense expert einsums run over full buffers (empty slots included).
    return router + active * cfg.capacity_factor


def _mamba_flops(cfg: ArchConfig) -> float:
    """Mamba2 block, per token (projections + chunked SSD)."""
    d, di = cfg.d_model, cfg.d_inner
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = cfg.ssm_chunk
    proj = 2.0 * d * (2 * di + 2 * h * n + h) + 2.0 * di * d
    conv = 2.0 * cfg.ssm_conv * di
    # SSD per token: intra-chunk scores (q x q per chunk -> q per token) over
    # heads x state, weighted sum over head_dim, plus state build/read.
    intra = 2.0 * q * h * n + 2.0 * q * h * p
    state = 2.0 * 2.0 * h * p * n
    return proj + conv + intra + state


def _mlstm_flops(cfg: ArchConfig) -> float:
    d = cfg.d_model
    di = 2 * d
    h = cfg.num_heads
    p = di // h
    q = cfg.ssm_chunk if cfg.ssm_chunk > 0 else 256
    proj = 2.0 * d * 2 * di + 2.0 * h * p * 3 * p + 2.0 * di * 2 * h + 2.0 * di * d
    intra = 2.0 * q * h * p + 2.0 * q * h * (p + 1)
    state = 2.0 * 2.0 * h * (p + 1) * p
    return proj + intra + state


def _slstm_flops(cfg: ArchConfig) -> float:
    d = cfg.d_model
    h = cfg.num_heads
    p = d // h
    return 2.0 * d * 4 * d + 2.0 * 4 * h * p * p + 2.0 * d * d


def forward_flops(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Global forward-pass FLOPs, itemized."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        t_q = float(b)           # one new token per sequence
        kv_len = float(s)
        causal = False           # one query over the full cache
    else:
        t_q = float(b) * s
        kv_len = float(s)
        causal = True

    d, v = cfg.d_model, cfg.padded_vocab
    items: dict[str, float] = {}
    items["embed_logits"] = 2.0 * t_q * d * v  # unembed matmul (gather ~free)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        per_tok = _attn_proj_flops(cfg) + _mlp_flops(cfg)
        items["blocks"] = cfg.num_layers * per_tok * t_q
        items["attention"] = cfg.num_layers * _attn_score_flops(cfg, t_q, kv_len, causal)
        if fam == "vlm":
            items["frontend"] = 2.0 * cfg.frontend_dim * d * float(b) * cfg.frontend_tokens
    elif fam == "moe":
        n_moe = cfg.num_layers - (1 if cfg.first_dense else 0)
        per_tok = _attn_proj_flops(cfg)
        items["blocks"] = cfg.num_layers * per_tok * t_q
        items["attention"] = cfg.num_layers * _attn_score_flops(cfg, t_q, kv_len, causal)
        items["moe"] = n_moe * _moe_flops(cfg) * t_q
        if cfg.first_dense:
            items["dense0"] = _mlp_flops(cfg) * t_q
    elif fam == "hybrid":
        napp = (cfg.num_layers + cfg.attn_every - 1) // max(cfg.attn_every, 1)
        items["mamba"] = cfg.num_layers * _mamba_flops(cfg) * t_q
        items["shared_attn"] = napp * (
            (_attn_proj_flops(cfg) + _mlp_flops(cfg)) * t_q
            + _attn_score_flops(cfg, t_q, kv_len, causal)
        )
    elif fam == "ssm":  # xLSTM
        pairs = cfg.num_layers // 2
        items["mlstm"] = pairs * _mlstm_flops(cfg) * t_q
        items["slstm"] = pairs * _slstm_flops(cfg) * t_q
    elif fam == "encdec":
        src = float(b) * (s if shape.kind != "decode" else min(s, 4096))
        enc_per_tok = _attn_proj_flops(cfg) + _mlp_flops(cfg)
        if shape.kind == "decode":
            items["encoder"] = 0.0  # memory precomputed at prefill
        else:
            # encoder self-attention: each of the src tokens attends over its
            # own sequence's src/b positions (non-causal).
            items["encoder"] = cfg.encoder_layers * (
                enc_per_tok * src + 2.0 * 2.0 * src * (src / b) * cfg.q_dim
            )
        dec_per_tok = _attn_proj_flops(cfg) * 2 + _mlp_flops(cfg)  # self + cross proj
        items["decoder"] = cfg.num_layers * dec_per_tok * t_q
        items["self_attn"] = cfg.num_layers * _attn_score_flops(cfg, t_q, kv_len, causal)
        cross_len = (s if shape.kind != "decode" else min(s, 4096))
        items["cross_attn"] = cfg.num_layers * _attn_score_flops(cfg, t_q, cross_len, False)
    else:
        raise ValueError(fam)
    items["total"] = sum(v for k, v in items.items() if k != "total")
    return items


_REMAT_EXTRA = {"none": 0.0, "dots": 0.5, "full": 1.0}


def step_cost(cfg: ArchConfig, shape: ShapeConfig, *, accum_steps: int = 1) -> StepCost:
    """Global per-step FLOPs + HBM bytes for the cell's step kind."""
    fwd = forward_flops(cfg, shape)
    n_params = cfg.param_count()
    b, s = shape.global_batch, shape.seq_len
    t_q = float(b) * (1 if shape.kind == "decode" else s)

    if shape.kind == "train":
        mult = 3.0 + _REMAT_EXTRA.get(cfg.remat, 1.0)
        flops = fwd["total"] * mult
        # weights: fwd read + bwd read (+ remat read) in bf16-compute fp32
        # master; grads + adam moments read/write in fp32.
        w_bytes = n_params * (FP32 * 2 + FP32 * 2 + FP32 * 4 * 2 + FP32 * 2)
        act_bytes = t_q * cfg.d_model * BF16 * cfg.num_layers * 4.0 * (1.0 / accum_steps + 1.0)
        logits_bytes = t_q * cfg.padded_vocab * FP32 * 2 / accum_steps
        hbm = w_bytes + act_bytes * accum_steps + logits_bytes * accum_steps
    elif shape.kind == "prefill":
        flops = fwd["total"]
        w_bytes = n_params * BF16
        act_bytes = t_q * cfg.d_model * BF16 * cfg.num_layers * 4.0
        kv_bytes = t_q * cfg.kv_dim * BF16 * 2 * cfg.num_layers
        hbm = w_bytes + act_bytes + kv_bytes
    else:  # decode
        flops = fwd["total"]
        w_bytes = n_params * BF16
        kv_el = 1 + 2.0 / cfg.head_dim if cfg.kv_cache_dtype == "int8" else BF16
        kv_read = float(b) * s * cfg.kv_dim * kv_el * 2 * _kv_layers(cfg)
        state_bytes = _state_bytes(cfg, b)
        hbm = w_bytes + kv_read + state_bytes
    return StepCost(flops=flops, hbm_bytes=hbm, details=fwd)


def _kv_layers(cfg: ArchConfig) -> int:
    """Layers holding a dense KV cache."""
    if cfg.family == "hybrid":
        return (cfg.num_layers + cfg.attn_every - 1) // max(cfg.attn_every, 1)
    if cfg.family == "ssm":
        return 0
    if cfg.family == "encdec":
        return 2 * cfg.num_layers  # self + cross
    return cfg.num_layers


def _state_bytes(cfg: ArchConfig, b: int) -> float:
    if cfg.family == "hybrid":
        per_layer = b * (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * FP32
                         + (cfg.ssm_conv - 1) * cfg.d_inner * BF16)
        return 2.0 * cfg.num_layers * per_layer  # read + write
    if cfg.family == "ssm":
        di = 2 * cfg.d_model
        h = cfg.num_heads
        p = di // h
        pairs = cfg.num_layers // 2
        m_state = b * h * (p + 1) * p * FP32
        s_state = 4 * b * cfg.d_model * FP32
        return 2.0 * pairs * (m_state + s_state)
    return 0.0
