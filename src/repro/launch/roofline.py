"""Roofline analysis: three terms per (arch x shape x mesh) from the dry-run.

    compute term    = FLOPs / (chips x 197e12)          [bf16 peak, v5e]
    memory term     = HBM bytes / (chips x 819e9)
    collective term = collective bytes / (chips x 50e9) [per-chip ICI]

Sources:

- FLOPs and HBM bytes: the analytic cost model (``launch.costs``), because
  XLA cost_analysis counts scan bodies once (measured; see costs.py).  The
  dry-run's measured per-device flops are reported alongside as the
  "body-once" cross-check.
- Collective bytes: structured HLO parse from the compiled program —
  top-level ops counted once, loop-body ops multiplied by the layer-scan
  trip count (x accum when microbatched).

Also reported per cell: the dominant term, MODEL_FLOPS = 6ND / 2ND / 2N_act
per kind, the usefulness ratio MODEL_FLOPS / analytic FLOPs, HBM fit, and a
one-line "what would move the dominant term" note.

Usage::

    PYTHONPATH=src python -m repro.launch.roofline --dryrun experiments/dryrun \
        --mesh 16x16 --out experiments/roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.registry import get_config, get_shape
from repro.launch import mesh as meshlib
from repro.launch.costs import step_cost
from repro.models.model_zoo import model_flops

HBM_PER_CHIP = 16 * 2**30  # v5e


def _scan_trip_count(arch: str, kind: str, accum: int) -> int:
    """Trip count multiplier for loop-body collectives."""
    cfg = get_config(arch)
    if cfg.family == "ssm":
        layers = cfg.num_layers // 2
    elif cfg.family == "moe" and cfg.first_dense:
        layers = cfg.num_layers - 1
    elif cfg.family == "encdec":
        layers = cfg.num_layers + cfg.encoder_layers  # two scans; upper bound
    else:
        layers = cfg.num_layers
    return layers * (accum if kind == "train" else 1)


def analyze_record(rec: dict) -> dict:
    """Roofline-classify one dryrun record (compute / memory / collective bound)."""
    import dataclasses

    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    if rec.get("cfg_overrides"):
        cfg = dataclasses.replace(cfg, **rec["cfg_overrides"])
    shape = get_shape(shape_name)
    chips = rec["devices"]
    accum = rec.get("accum_steps", 1)

    cost = step_cost(cfg, shape, accum_steps=accum)
    t_compute = cost.flops / (chips * meshlib.PEAK_FLOPS_BF16)
    t_memory = cost.hbm_bytes / (chips * meshlib.HBM_BW)

    cs = rec.get("collective_bytes_structured")
    if cs:
        trips = _scan_trip_count(arch, shape.kind, accum)
        coll_dev = cs["top"].get("total", 0) + cs["body"].get("total", 0) * trips
    else:
        coll_dev = rec["collective_bytes"].get("total", 0)
    # Parsed bytes are per-device already (SPMD module).
    t_coll = coll_dev / meshlib.ICI_LINK_BW

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / max(cost.flops, 1.0)
    bound = max(terms.values())
    frac = {  # roofline fraction: useful compute time / bound time
        k: (mf / (chips * meshlib.PEAK_FLOPS_BF16)) / max(bound, 1e-30) for k in ("x",)
    }["x"]
    peak_mem = rec["memory"]["peak_bytes_est"]
    return {
        **{k: rec[k] for k in ("arch", "shape", "kind", "mesh", "devices")},
        "accum": accum,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "analytic_flops": cost.flops,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hlo_flops_per_dev_body_once": rec["flops_per_device"],
        "peak_mem_gib": peak_mem / 2**30,
        "fits_hbm": peak_mem <= HBM_PER_CHIP,
        "advice": _advice(dominant, cfg, shape),
    }


def _advice(dominant: str, cfg, shape) -> str:
    if dominant == "compute":
        if cfg.family == "moe":
            return "compute-bound: cut capacity-factor slack / drop remat to 'dots'"
        return "compute-bound: near roofline ceiling; reduce remat recompute"
    if dominant == "memory":
        if shape.kind == "decode":
            return "KV/weight streaming bound: quantize KV to int8, batch more decode streams"
        return "activation traffic bound: increase accumulation, fuse norms, blockwise CE"
    return "collective-bound: overlap per-layer all-gathers with compute; shrink grad payload (int8 EF)"


def load_records(dryrun_dir: str, mesh: str) -> list[dict]:
    """Load every dryrun JSON record for ``mesh`` from ``dryrun_dir``."""
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*_{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def to_markdown(rows: list[dict]) -> str:
    """Render analyzed roofline rows as a markdown table."""
    hdr = (
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL/HLO | roofline frac | peak mem (GiB) | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['peak_mem_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    """CLI: aggregate dryrun records into a roofline report."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    rows = [analyze_record(r) for r in load_records(args.dryrun, args.mesh)]
    md = to_markdown(rows)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md)
        with open(args.out.replace(".md", ".json"), "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
