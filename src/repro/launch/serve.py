"""Serving launcher: the energy-first control plane end-to-end.

Serves real (reduced) models on this host as FaaS function classes, meters
every invocation, and reports FaasMeter energy footprints + prices — the
paper's full pipeline (Fig. 1) on live compute::

    PYTHONPATH=src python -m repro.launch.serve --archs internlm2-1.8b,xlstm-350m \
        --requests 40 --batch 2 --seq 64
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.configs.shapes import ShapeConfig
from repro.core.profiler import FaasMeterProfiler, ProfilerConfig
from repro.core.pricing import PricingConfig, price_report
from repro.models import build
from repro.models.common import materialize
from repro.serving.control_plane import MeteredServer
from repro.serving.engine import ServeEngine
from repro.telemetry.simulator import NodeSimulator, SimulatorConfig
from repro.workload.functions import FunctionRegistry, FunctionSpec

import jax.numpy as jnp


def main() -> None:
    """CLI: continuous-batching serving smoke across model-zoo architectures."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="internlm2-1.8b,xlstm-350m,olmoe-1b-7b")
    ap.add_argument("--requests", type=int, default=30)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--gen-steps", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    archs = args.archs.split(",")
    shape = ShapeConfig("serve", args.seq, args.batch, "prefill")
    server = MeteredServer()
    rng = np.random.default_rng(args.seed)

    print("== registering function classes (reduced configs, real compute) ==")
    for name in archs:
        cfg = get_config(name, reduced=True)
        api = build(cfg)
        params = materialize(api.params_def, jax.random.PRNGKey(args.seed))
        engine = ServeEngine(api, shape, params)
        batch = {}
        for k, sp in api.prefill_inputs(shape).items():
            if np.issubdtype(np.dtype(sp.dtype), np.integer):
                batch[k] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, size=sp.shape), jnp.int32
                )
            else:
                batch[k] = jnp.asarray(rng.standard_normal(sp.shape) * 0.1, sp.dtype)
        server.register(f"{name}/generate", engine, batch, steps=args.gen_steps)
        print(f"  {name}/generate registered")

    schedule = [
        (f"{archs[i % len(archs)]}/generate", 0.0) for i in range(args.requests)
    ]
    print(f"== serving {len(schedule)} requests ==")
    trace = server.serve(schedule, duration=60.0)
    lat = trace.end - trace.start
    print(f"   measured warm latencies: mean={lat.mean():.3f}s p95={np.quantile(lat, 0.95):.3f}s")

    # Meter the measured trace through the telemetry substrate + profiler.
    specs = []
    for i, name in enumerate(server.order):
        mask = trace.fn_id == i
        mean_lat = float(lat[mask].mean()) if mask.any() else 0.1
        specs.append(
            FunctionSpec(name, mean_lat, 0.2, dyn_power_w=25.0 + 5.0 * i, cpu_frac=0.9)
        )
    registry = FunctionRegistry(specs)
    sim = NodeSimulator(registry, SimulatorConfig(platform="desktop")).simulate(trace)
    report = FaasMeterProfiler(ProfilerConfig(init_windows=20, step_windows=10)).profile(
        jnp.asarray(trace.fn_id), jnp.asarray(trace.start), jnp.asarray(trace.end),
        num_fns=trace.num_fns, duration=trace.duration, telemetry=sim.telemetry,
    )
    prices = price_report(
        report.spectrum.j_indiv, report.spectrum.j_total, report.invocations,
        report.mean_latency, jnp.ones(trace.num_fns), PricingConfig(),
    )
    print("== FaasMeter footprints ==")
    for i, name in enumerate(server.order):
        print(
            f"  {name:32s} J/inv={float(report.spectrum.per_invocation[i]):8.2f} "
            f"(indiv {float(report.spectrum.per_invocation_indiv[i]):7.2f}) "
            f"usd/inv={float(prices['total_usd_per_inv'][i]):.2e} "
            f"carbon g/inv={float(prices['carbon_g_per_inv'][i]):.3f}"
        )
    print(f"  total-error={report.total_error:.3f} skew={report.skew_windows:+.1f}w")


if __name__ == "__main__":
    main()
