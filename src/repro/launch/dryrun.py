import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_EXTRA", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device count
at first init, and the dry-run needs 512 placeholder host devices so
``jax.make_mesh`` can build the production meshes.  Nothing here allocates
tensors: parameters, optimizer state, batches, and KV caches all enter as
ShapeDtypeStructs.

Per cell this script prints/records:

- ``compiled.memory_analysis()``  -> bytes per device (proves it fits)
- ``compiled.cost_analysis()``    -> FLOPs / bytes for the roofline
- collective bytes parsed from the compiled HLO (all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute)

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_shape, runnable_cells
from repro.distributed import sharding as shd
from repro.distributed.collectives import collective_bytes, collective_bytes_structured
from repro.launch import mesh as meshlib
from repro.models import build, model_flops
from repro.models.common import abstract, logical_axes
from repro.models.model_zoo import spec_abstract, spec_logical
from repro.training import optimizer as opt
from repro.training.train_step import (
    abstract_state,
    make_train_step,
    state_logical,
)


def _shardings_from_logical(logical_tree, abstract_tree, mesh, rules):
    return jax.tree.map(
        lambda ax, a: shd.sharding_for(ax, a.shape, mesh, rules),
        logical_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def lower_cell(
    arch: str, shape_name: str, *, multi_pod: bool = False, opt_config=None,
    accum_steps: int = 1, cast_params: bool = False, rules_name: str = "train",
    cfg_overrides: dict | None = None,
):
    """Lower + compile one cell.  Returns the record dict."""
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = get_shape(shape_name)
    api = build(cfg)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    opt_config = opt_config or opt.OptimizerConfig()

    t0 = time.time()
    if shape.kind == "train":
        rules = {"zero3": shd.ZERO3_RULES, "ep": shd.EP_RULES}.get(rules_name, shd.TRAIN_RULES)
        step = make_train_step(
            api, opt_config, accum_steps=accum_steps, cast_params=cast_params
        )
        st_abs = abstract_state(api, opt_config)
        st_sh = _shardings_from_logical(state_logical(api, opt_config), st_abs, mesh, rules)
        b_specs = api.train_inputs(shape)
        b_abs = spec_abstract(b_specs)
        b_sh = _shardings_from_logical(spec_logical(b_specs), b_abs, mesh, rules)
        with shd.use_rules(mesh, rules):
            jitted = jax.jit(
                step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(st_abs, b_abs)
    elif shape.kind == "prefill":
        rules = shd.SERVE_RULES
        p_abs = abstract(api.params_def, jnp.bfloat16)
        p_sh = _shardings_from_logical(logical_axes(api.params_def), p_abs, mesh, rules)
        b_specs = api.prefill_inputs(shape)
        b_abs = spec_abstract(b_specs)
        b_sh = _shardings_from_logical(spec_logical(b_specs), b_abs, mesh, rules)
        with shd.use_rules(mesh, rules):
            jitted = jax.jit(api.prefill, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_abs, b_abs)
    else:  # decode
        rules = shd.SERVE_RULES
        p_abs = abstract(api.params_def, jnp.bfloat16)
        p_sh = _shardings_from_logical(logical_axes(api.params_def), p_abs, mesh, rules)
        c_specs = api.cache_spec(shape)
        c_abs = spec_abstract(c_specs)
        c_sh = _shardings_from_logical(spec_logical(c_specs), c_abs, mesh, rules)
        d_specs = api.decode_inputs(shape)
        d_abs = spec_abstract(d_specs)
        d_sh = _shardings_from_logical(spec_logical(d_specs), d_abs, mesh, rules)
        with shd.use_rules(mesh, rules):
            jitted = jax.jit(
                api.decode,
                in_shardings=(p_sh, c_sh, d_sh["token"], d_sh["pos"]),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(p_abs, c_abs, d_abs["token"], d_abs["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    coll_structured = collective_bytes_structured(hlo_text)

    n_dev = 512 if multi_pod else 256
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "accum_steps": accum_steps,
        "cast_params": cast_params,
        "rules": rules_name if shape.kind == "train" else "serve",
        "cfg_overrides": cfg_overrides or {},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_bytes_structured": coll_structured,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_bytes_est": int(
                mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes
            ),
        },
        "model_flops": model_flops(cfg, shape),
        "param_count": cfg.param_count(),
    }
    return record


def main() -> None:
    """CLI: AOT-compile (arch, shape) cells and dump memory/collective records."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--rules", default="train", choices=["train", "zero3", "ep"])
    ap.add_argument("--override", default="", help="k=v,... ArchConfig overrides")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    overrides = {}
    for kv in args.override.split(","):
        if kv:
            k, v = kv.split("=")
            for cast in (int, float, str):
                try:
                    overrides[k] = cast(v)
                    break
                except ValueError:
                    continue

    cells = runnable_cells() if args.all else [(args.arch, args.shape)]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}{args.tag}"
        try:
            rec = lower_cell(
                arch, shape, multi_pod=args.multi_pod, accum_steps=args.accum,
                cast_params=args.bf16_params, rules_name=args.rules,
                cfg_overrides=overrides,
            )
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"OK  {tag:60s} compile={rec['compile_s']:7.1f}s "
                f"flops/dev={rec['flops_per_device']:.3e} "
                f"peak_mem/dev={rec['memory']['peak_bytes_est']/2**30:.2f}GiB "
                f"coll={rec['collective_bytes'].get('total', 0)/2**20:.1f}MiB",
                flush=True,
            )
        except Exception:
            failures += 1
            print(f"FAIL {tag}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
