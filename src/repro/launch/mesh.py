"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state (the dry-run sets device-count env
flags before first jax init; everything else sees the real 1-CPU host)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-host mesh for smoke tests and CPU examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_LINK_BW = 50e9            # bytes/s per link (~4 links/chip on v5e torus)
CHIP_IDLE_W = 60.0            # telemetry power-model floor
CHIP_DYN_W = 160.0            # dynamic watts at full utilization
