"""Energy-aware FaaS scheduler: queueing, keep-alive, power-capped admission.

The scheduler is the control-plane component FaasMeter §5 instruments:

- **Queue + admission**: invocations queue per function class; the head of
  the queue is admitted iff the power cap allows it, using the function's
  FaasMeter footprint J_lambda as the predicted energy increment
  (``core.capping.PowerCapController``).  Without a footprint, the static
  buffer fallback applies — the paper's comparison.
- **Keep-alive**: warm engines (params + compiled executables + resident
  caches) are retained greedy-dual style (cost = cold-start latency x
  frequency / residency bytes); eviction -> next invocation is a cold start.
- **Straggler mitigation**: invocations exceeding ``timeout_factor`` x the
  class's mean latency are cancelled and requeued (bounded retries), and the
  node is flagged — the serving-side analogue of the trainer watchdog.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict, deque
from typing import Any, Callable

import numpy as np

from repro.core.capping import CappingConfig, FleetPowerCapController, PowerCapController


@dataclasses.dataclass
class Invocation:
    function: str
    arrival: float
    payload: Any = None
    retries: int = 0
    admitted_at: float | None = None
    started_at: float | None = None
    finished_at: float | None = None

    @property
    def queue_wait(self) -> float:
        return (self.started_at or self.arrival) - self.arrival


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    capping: CappingConfig = CappingConfig()
    keep_alive_bytes: int = 8 << 30      # residency budget for warm engines
    timeout_factor: float = 5.0          # straggler cutoff vs class mean
    max_retries: int = 2


@dataclasses.dataclass
class _WarmEntry:
    engine: Any
    bytes: int
    freq: float = 0.0
    cold_cost_s: float = 0.0
    credit: float = 0.0  # greedy-dual credit


class KeepAliveCache:
    """Greedy-dual keep-alive (paper [40], FaasCache) over warm engines."""

    def __init__(self, budget_bytes: int):
        self.budget = budget_bytes
        self.entries: dict[str, _WarmEntry] = {}
        self._clock = 0.0

    def get(self, fn: str) -> Any | None:
        e = self.entries.get(fn)
        if e is None:
            return None
        e.freq += 1.0
        e.credit = self._clock + e.cold_cost_s * e.freq / max(e.bytes, 1)
        return e.engine

    def put(self, fn: str, engine: Any, nbytes: int, cold_cost_s: float) -> list[str]:
        """Insert a warm engine; returns the list of evicted functions.

        Re-putting a resident function replaces its entry in place: its old
        bytes are released *before* the budget check (so they are never
        double-counted against itself) and it can never be chosen as its own
        eviction victim; its access frequency carries over.  A budget that
        lands exactly exhausted (used + nbytes == budget) admits without
        evicting — the greedy-dual rule only fires strictly past the budget.
        """
        evicted = []
        prev = self.entries.pop(fn, None)
        used = sum(e.bytes for e in self.entries.values())
        while self.entries and used + nbytes > self.budget:
            victim = min(self.entries, key=lambda k: self.entries[k].credit)
            self._clock = self.entries[victim].credit  # greedy-dual aging
            used -= self.entries[victim].bytes
            del self.entries[victim]
            evicted.append(victim)
        e = _WarmEntry(
            engine=engine, bytes=nbytes, cold_cost_s=cold_cost_s,
            freq=(prev.freq + 1.0) if prev is not None else 1.0,
        )
        e.credit = self._clock + cold_cost_s * e.freq / max(nbytes, 1)
        self.entries[fn] = e
        return evicted

    @property
    def resident(self) -> set[str]:
        return set(self.entries)


def energy_aware_placement(
    fleet: FleetPowerCapController,
    footprint_joules: float | None,
    duration_s: float | None = None,
    *,
    live=None,
) -> int | None:
    """GreenFaaS-style energy-aware placement over a capped fleet.

    Candidate nodes are tried in descending cap headroom (the node with the
    most watts to spare under its guarded cap first); the first node whose
    admission rule accepts wins and is charged (``admit`` — stats plus the
    optimistic power accounting), losers are only probed (``would_admit``,
    no side effects).  Returns the winning node index, or None when no live
    node can take the invocation this control interval (the caller defers
    it).  ``live`` (B,) bool restricts candidates to still-streaming nodes.
    """
    order = np.argsort(-fleet.headroom_watts(), kind="stable")
    for i in order:
        i = int(i)
        if live is not None and not live[i]:
            continue
        if fleet.would_admit(i, footprint_joules, duration_s):
            fleet.admit(i, footprint_joules, duration_s)
            return i
    return None


@dataclasses.dataclass
class SchedulerStats:
    completed: int = 0
    cold_starts: int = 0
    requeued: int = 0
    deferred_by_cap: int = 0
    queue_waits: list = dataclasses.field(default_factory=list)
    latencies: list = dataclasses.field(default_factory=list)


class EnergyAwareScheduler:
    """Single-node scheduler driving the simulated/real execution substrate.

    ``executor(inv) -> latency_s`` performs the invocation;
    ``footprint_of(fn) -> J | None`` supplies FaasMeter footprints.
    """

    def __init__(
        self,
        config: SchedulerConfig,
        executor: Callable[[Invocation], float],
        footprint_of: Callable[[str], float | None],
        *,
        mean_latency_of: Callable[[str], float] | None = None,
    ):
        self.config = config
        self.executor = executor
        self.footprint_of = footprint_of
        self.mean_latency_of = mean_latency_of or (lambda fn: 1.0)
        self.cap = PowerCapController(config.capping)
        self.queue: deque[Invocation] = deque()
        self.stats = SchedulerStats()
        self._lat_acc: dict[str, list[float]] = defaultdict(list)

    def submit(self, inv: Invocation) -> None:
        self.queue.append(inv)

    def observe_power(self, watts: float) -> None:
        self.cap.observe_power(watts)

    def drain(self, now: float = 0.0) -> int:
        """Admit + run queued invocations while the power cap allows."""
        ran = 0
        while self.queue:
            inv = self.queue[0]
            if not self.cap.admit(self.footprint_of(inv.function)):
                self.stats.deferred_by_cap += 1
                break
            self.queue.popleft()
            inv.admitted_at = now
            inv.started_at = now
            latency = self.executor(inv)
            mean = self.mean_latency_of(inv.function)
            if latency > self.config.timeout_factor * mean and inv.retries < self.config.max_retries:
                inv.retries += 1
                self.stats.requeued += 1
                self.queue.append(inv)  # straggler: retry at the tail
                continue
            inv.finished_at = now + latency
            self.stats.completed += 1
            self.stats.queue_waits.append(inv.queue_wait)
            self.stats.latencies.append(latency)
            self._lat_acc[inv.function].append(latency)
            ran += 1
        return ran

    def drain_fleet(
        self,
        now: float,
        *,
        fleet: FleetPowerCapController,
        placement: bool = True,
        live=None,
    ) -> list[tuple[Invocation, int]]:
        """Admit + place queued invocations across a capped fleet.

        The fleet twin of ``drain``: the head of the queue is placed via
        ``energy_aware_placement`` (descending cap headroom, first node whose
        footprint-aware rule admits) and *not executed here* — the caller
        (the streaming ``ControlLoop``) re-injects placed invocations into
        the simulator, which is where their power shows up.  Head-of-line
        blocking is deliberate: when no node can take the head this control
        interval, everything behind it waits too (FIFO fairness, same as the
        single-node path).  With ``placement=False`` each invocation may
        only run on its origin node (``inv.payload["node"]``) — the
        no-migration baseline.  Returns ``[(invocation, node), ...]`` for
        the invocations admitted at ``now``.
        """
        placed = []
        while self.queue:
            inv = self.queue[0]
            j = self.footprint_of(inv.function)
            dur = self.mean_latency_of(inv.function)
            if placement:
                node = energy_aware_placement(fleet, j, dur, live=live)
            else:
                node = inv.payload["node"] if isinstance(inv.payload, dict) else 0
                if live is not None and not live[node]:
                    node = None
                elif not fleet.admit(node, j, dur):
                    node = None
            if node is None:
                self.stats.deferred_by_cap += 1
                break
            self.queue.popleft()
            inv.admitted_at = now
            # An invocation admitted in the same control window it arrived
            # keeps its arrival time (no wait); a deferred one starts at the
            # admitting window.
            inv.started_at = max(now, inv.arrival)
            self.stats.completed += 1
            self.stats.queue_waits.append(inv.queue_wait)
            placed.append((inv, node))
        return placed


@dataclasses.dataclass
class SlotRequest:
    """One node waiting for a slot in a ``SlotFleetSession`` pool.

    Carries everything ``SlotFleetSession.admit`` needs: the node id plus
    either a warm-start estimate (``x0``) or the raw init-block windows
    (``init_c``/``init_w``) from which the pool runs a bucketed init solve.
    """

    node: int
    init_c: Any = None
    init_w: Any = None
    x0: Any = None


class SlotAdmissionQueue:
    """FIFO admission control feeding a ``SlotFleetSession`` slot pool.

    The serving analogue of ``KeepAliveCache``: joins that arrive while the
    pool is full wait here in arrival order instead of raising, and every
    ``drain()`` (typically once per control interval, after retirements have
    released slots) admits waiting nodes head-first while capacity and the
    optional admission ``gate`` allow.  The gate is the capacity-aware
    admission hook — e.g. ``lambda req: fleet.headroom_watts().max() > 0``
    defers joins when no capped node has watts to spare.

    Head-of-line blocking is deliberate and matches ``EnergyAwareScheduler``:
    admission order is arrival order, so a gated head request parks the
    whole queue until the gate clears.
    """

    def __init__(self, pool, *, gate: Callable[[SlotRequest], bool] | None = None):
        self.pool = pool
        self.gate = gate
        self._queue: deque[SlotRequest] = deque()
        self.admitted: list[int] = []
        self.deferred = 0

    @property
    def pending(self) -> int:
        """Number of joins still waiting for a slot."""
        return len(self._queue)

    def submit(self, node: int, init_c=None, init_w=None, *, x0=None) -> int | None:
        """Enqueue a join; admit immediately when a slot is free.

        Returns the slot index when the node was admitted on the spot, or
        None when it was queued (pool full, earlier joins waiting, or the
        gate deferred it).
        """
        self._queue.append(SlotRequest(node, init_c, init_w, x0))
        admitted = self.drain()
        for n, slot in admitted:
            if n == node:
                return slot
        return None

    def drain(self) -> list[tuple[int, int]]:
        """Admit queued joins in FIFO order while slots and the gate allow.

        Returns ``[(node, slot), ...]`` for every admission made this call.
        """
        placed: list[tuple[int, int]] = []
        while self._queue and self.pool.free_slots > 0:
            req = self._queue[0]
            if self.gate is not None and not self.gate(req):
                self.deferred += 1
                break
            slot = self.pool.admit(
                req.node, req.init_c, req.init_w, x0=req.x0
            )
            self._queue.popleft()
            self.admitted.append(req.node)
            placed.append((req.node, slot))
        return placed
