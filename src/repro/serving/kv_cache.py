"""Decode-cache management: allocation, residency, and keep-alive accounting.

Caches are family-specific pytrees described by ``ModelApi.cache_spec``; this
module materializes them (zeros), tracks residency bytes (the FaaS keep-alive
analogue: a warm function's sandbox = a resident cache + weights), and gives
the scheduler the eviction-cost signal.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeConfig
from repro.models.model_zoo import ModelApi, is_spec


def init_cache(api: ModelApi, shape: ShapeConfig, *, shardings: Any = None) -> Any:
    """Zero-filled decode cache matching ``cache_spec(shape)``."""
    spec = api.cache_spec(shape)

    def make(s, sh=None):
        z = jnp.zeros(s.shape, s.dtype)
        # sLSTM stabilizer state must start at -inf-like.
        return jax.device_put(z, sh) if sh is not None else z

    if shardings is not None:
        cache = jax.tree.map(make, spec, shardings, is_leaf=is_spec)
    else:
        cache = jax.tree.map(make, spec, is_leaf=is_spec)
    if "s_m" in cache if isinstance(cache, dict) else False:
        cache["s_m"] = jnp.full_like(cache["s_m"], -1e30)
    return cache


def cache_bytes(api: ModelApi, shape: ShapeConfig) -> int:
    """Residency bytes of one warm cache (keep-alive memory accounting)."""
    spec = api.cache_spec(shape)
    total = 0
    for s in jax.tree.leaves(spec, is_leaf=is_spec):
        total += math.prod(s.shape) * np.dtype(s.dtype).itemsize
    return total


def params_bytes(api: ModelApi, dtype_bytes: int = 4) -> int:
    """Model parameter bytes at ``dtype_bytes`` per element."""
    from repro.models.common import is_param

    total = 0
    for p in jax.tree.leaves(api.params_def, is_leaf=is_param):
        total += math.prod(p.shape) * dtype_bytes
    return total
