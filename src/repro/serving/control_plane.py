"""Energy-first FaaS control plane (paper Fig. 1, §5, §6.3).

Ties together workload -> execution -> telemetry -> FaasMeter profiling ->
footprints -> pricing/capping, in two execution substrates:

- ``EnergyFirstControlPlane.profile_trace``: trace-driven (invocations carry
  their latencies; power comes from the telemetry simulator).  All paper
  benchmarks run through this — the profiler sees only degraded signals.
- ``EnergyFirstControlPlane.profile_fleet``: the *streaming* fleet path —
  telemetry is fed window-by-window into a ``StreamingFleetSession``, each
  engine tick updates every node's ``StreamingFootprintTracker`` live, and
  the ``on_tick`` hook exposes conserved per-tick attribution for online
  pricing/capping (docs/streaming.md, examples/stream_energy.py).
- ``EnergyFirstControlPlane.run_capped``: discrete-event execution under a
  software power cap (paper Fig. 10): arrivals queue, the head of the queue
  is admitted iff ``W*t + J_lambda <= W_cap*t`` using live FaasMeter
  footprints, and deferred invocations wait — reproducing the cap/latency
  trade-off and the <3 % overshoot claim.
- ``MeteredServer`` (real-exec): actual jitted model invocations on this
  host, timed, traced, and profiled — the end-to-end serving driver.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.capping import (
    CappingConfig,
    FleetPowerCapController,
    PowerCapController,
)
from repro.core.pricing import LivePriceMeter, PricingConfig, price_report
from repro.core.profiler import (
    FaasMeterProfiler,
    FootprintReport,
    ProfilerConfig,
    fleet_profile,
    prepare_combined_fleet,
    segment_plan,
)
from repro.serving.scheduler import (
    EnergyAwareScheduler,
    Invocation,
    SchedulerConfig,
)
from repro.telemetry.simulator import (
    FleetTelemetryTick,
    NodeSimulator,
    SimResult,
    SimulatorConfig,
)
from repro.workload.functions import FunctionRegistry
from repro.workload.trace import InvocationTrace

import jax.numpy as jnp


@dataclasses.dataclass
class ProfiledWorkload:
    """One node's profiling outcome: report + simulation + prices.

    ``footprint_stream`` is the node's live-fed footprint tracker when the
    workload went through the streaming fleet path (None on the per-node /
    short-segment fallbacks).
    """

    report: FootprintReport
    sim: SimResult
    trace: InvocationTrace
    prices: dict
    footprint_stream: "StreamingFootprintTracker | None" = None


class StreamingFootprintTracker:
    """Streaming per-invocation footprint state for one node.

    The seed recomputed the whole footprint spectrum from scratch whenever a
    caller wanted fresh per-invocation numbers.  This tracker instead folds
    each observation — a whole Kalman step, or, on the live path, every
    single telemetry tick — into running footprints in O(M), so the control
    plane can serve per-invocation footprints (for pricing and capping
    admission) that are always current without any recomputation over
    history.  ``profile_fleet`` feeds it *live per tick* from the streaming
    engine (``observe_tick``); ``observe_step`` remains for coarse feeds
    (the init-segment seed, or replaying per-step trajectories).
    """

    def __init__(self, num_fns: int, idle_watts: float = 0.0):
        self.num_fns = num_fns
        self.idle_watts = idle_watts
        self.j_indiv = np.zeros(num_fns)        # cumulative attributed joules
        self.invocations = np.zeros(num_fns)    # cumulative invocation counts
        self.elapsed_s = 0.0
        self.steps_seen = 0                     # observations folded in (any kind)
        self.ticks_seen = 0                     # of which: live per-tick feeds

    def observe_step(
        self,
        x_step: np.ndarray,       # (M,) per-function power estimate after the step
        busy_seconds: np.ndarray,  # (M,) per-function runtime within the step
        a_step: np.ndarray,       # (M,) invocations in the step
        step_seconds: float,
    ) -> None:
        """Fold one Kalman step (or any coarse observation) into the state.

        Args:
          x_step: (M+,) per-function power estimate for the interval (W);
            entries past ``num_fns`` (shared principals) are ignored.
          busy_seconds: (M+,) per-function runtime within the interval (s).
          a_step: (M+,) invocations starting in the interval.
          step_seconds: interval length (s), for the idle-energy share.
        """
        self.j_indiv += np.asarray(busy_seconds[: self.num_fns], float) * np.asarray(
            x_step[: self.num_fns], float
        )
        self.invocations += np.asarray(a_step[: self.num_fns], float)
        self.elapsed_s += step_seconds
        self.steps_seen += 1

    def observe_tick(
        self,
        x_tick: np.ndarray,
        busy_seconds: np.ndarray,
        a_tick: np.ndarray,
        tick_seconds: float,
    ) -> None:
        """Fold one *live* engine tick (streaming path) into the state.

        Same accumulation as ``observe_step`` at tick granularity — the
        estimate used is the causal one current at the tick, so footprints
        move the moment the streaming engine's estimate does.
        """
        self.observe_step(x_tick, busy_seconds, a_tick, tick_seconds)
        self.ticks_seen += 1

    @property
    def per_invocation_indiv(self) -> np.ndarray:
        """(M,) running J/invocation of function execution alone."""
        return np.where(
            self.invocations > 0, self.j_indiv / np.maximum(self.invocations, 1.0), 0.0
        )

    @property
    def per_invocation_total(self) -> np.ndarray:
        """(M,) running J/invocation including the even idle-energy share
        over currently-active functions (§4.4 static-resource policy)."""
        active = self.invocations > 0
        n_active = max(int(active.sum()), 1)
        idle_j = self.idle_watts * self.elapsed_s / n_active
        total = self.j_indiv + np.where(active, idle_j, 0.0)
        return np.where(active, total / np.maximum(self.invocations, 1.0), 0.0)


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Knobs for the streaming ``ControlLoop``.

    ``cap_watts`` is the per-node power cap (sensed system watts, same scale
    as the telemetry the loop observes).  ``capping`` overrides the derived
    ``CappingConfig`` wholesale when set.  ``placement=False`` pins every
    invocation to its origin node (the no-migration baseline);
    ``retrain``/``resync_every_steps`` gate the live model-maintenance side
    (combined mode only).
    """

    cap_watts: float
    use_footprints: bool = True
    placement: bool = True
    retrain: bool = True
    retrain_window_steps: int = 2
    resync_every_steps: int = 0
    # End-of-segment drain packs deferred work to cap*(1 - drain_margin):
    # footprints are estimates (and the host's power curve is sublinear in
    # concurrency), so packing to the exact cap would park every drain
    # window at the cap edge where estimate noise flips it over.
    drain_margin: float = 0.1
    pricing: PricingConfig = PricingConfig()
    capping: CappingConfig | None = None


class ControlLoop:
    """Closed-loop energy control over the live streaming fleet replay.

    This is the feedback layer that turns the profiler into a controller
    (paper Fig. 1: energy as a first-class control operation).  Driven from
    ``profile_fleet(control=...)``'s tick path, each conserved engine tick:

    1. feeds every node's sensed power to a per-node
       ``PowerCapController.observe_power`` (AIMD guard bands stay
       node-local, ``core.capping.FleetPowerCapController``);
    2. folds the tick's conserved attribution into a ``LivePriceMeter`` —
       the per-function bill is always current during the segment;
    3. submits the window's new arrivals to the ``EnergyAwareScheduler``
       and drains it: the head of the queue is placed on the node with the
       most cap headroom whose footprint-aware rule admits it
       (``scheduler.energy_aware_placement``), using *live* tracker
       footprints as J_lambda.  An invocation no node can take stays
       queued — deferred — and re-starts at the window that finally admits
       it, so capping visibly reshapes the trace;
    4. at Kalman-step boundaries, runs the model-maintenance side: when the
       session's ``retrain_needed`` fires, flagged nodes' counter models
       are re-fit on a sliding window in one fleet-batched call and swapped
       in without retracing (``session.refit_counter_models``); sync skew
       is re-estimated every ``resync_every_steps`` steps
       (``session.resync``).

    The loop is causal: decisions at tick ``t`` use only telemetry and
    footprints up to ``t``.  Telemetry was recorded from the *uncontrolled*
    replay, so within the loop the observed power is the baseline's — one
    control round against the live stream.  The controlled schedule's actual
    effect is then measured by re-simulating ``controlled_traces()`` (the
    reshaped per-node traces) through the same simulator; the paper's
    overshoot comparison (and the conservation tests) run on that second
    pass.  Arrivals inside the bootstrap init segment (no footprints yet)
    and past the engine's last full Kalman step pass through uncontrolled —
    the controller only reshapes what it could actually observe.
    """

    def __init__(self, config: ControlConfig):
        self.config = config
        self.session = None
        self.fleet: FleetPowerCapController | None = None
        self.meter: LivePriceMeter | None = None
        self.scheduler: EnergyAwareScheduler | None = None
        self.retrain_events: list[tuple[int, np.ndarray]] = []
        self.resync_events: list[int] = []
        self.drain_waits: list[float] = []
        self.ticks_seen = 0
        self._bound = False
        self._finished = False

    # -- wiring (called by profile_fleet) ----------------------------------

    def bind(
        self,
        *,
        traces: list[InvocationTrace],
        registry: FunctionRegistry,
        trackers: list,
        idle_watts,
        delta: float,
        init_n: int,
        n_used: int,
    ) -> None:
        """Attach the loop to one replay: precompute the fleet-wide arrival
        stream, build the capped-fleet controller, the live price meter, and
        the scheduler.  Arrivals before the init boundary are recorded into
        the controlled schedule verbatim (the controller has no footprints
        yet); everything from the init boundary to the engine's last tick is
        subject to admission control."""
        if self._bound:
            raise ValueError("ControlLoop is single-use: already bound to a replay")
        self._bound = True
        cfg = self.config
        self.registry = registry
        self.trackers = trackers
        self.delta = delta
        self.init_n = init_n
        self.n_used = n_used
        self.b = len(traces)
        self.num_fns = traces[0].num_fns
        self.idle = np.asarray(idle_watts, float)
        self.orig_duration = max(t.duration for t in traces)
        capping = cfg.capping or CappingConfig(
            power_cap_watts=cfg.cap_watts,
            control_interval_s=delta,
            use_footprints=cfg.use_footprints,
        )
        self.fleet = FleetPowerCapController(capping, self.b)
        self.meter = LivePriceMeter(self.num_fns, cfg.pricing)
        self.scheduler = EnergyAwareScheduler(
            SchedulerConfig(capping=capping),
            executor=lambda inv: inv.payload["dur"],
            footprint_of=self._footprint_of,
            mean_latency_of=lambda fn: self.registry[fn].mean_latency_s,
        )
        # Fleet-wide arrival stream, start-ordered (numpy, no Python loop
        # over 1e5 invocations).
        fns, starts, durs, nodes = [], [], [], []
        for i, tr in enumerate(traces):
            valid = tr.fn_id >= 0
            fns.append(tr.fn_id[valid].astype(np.int64))
            starts.append(tr.start[valid].astype(np.float64))
            durs.append((tr.end - tr.start)[valid].astype(np.float64))
            nodes.append(np.full(int(valid.sum()), i, np.int64))
        fns = np.concatenate(fns) if fns else np.zeros(0, np.int64)
        starts = np.concatenate(starts) if fns.size else np.zeros(0)
        durs = np.concatenate(durs) if fns.size else np.zeros(0)
        nodes = np.concatenate(nodes) if fns.size else np.zeros(0, np.int64)
        order = np.argsort(starts, kind="stable")
        self._arr_fn = fns[order]
        self._arr_t = starts[order]
        self._arr_dur = durs[order]
        self._arr_node = nodes[order]
        # Controlled schedule under construction: per node [(fn, start, dur)].
        self._controlled: list[list[tuple[int, float, float]]] = [
            [] for _ in range(self.b)
        ]
        # Power the loop itself moved into future windows: re-injected
        # deferred (or migrated) invocations run where the observed baseline
        # telemetry has no trace of them, so the controller must charge
        # itself for them or it over-admits on top of its own shifted load.
        # Entries are (node, end_t, nameplate watts).
        self._shifted: list[tuple[int, float, float]] = []
        self._nameplate = np.asarray(
            [s.dyn_power_w for s in registry.specs], float
        )
        # Pass the init segment through verbatim (bulk slice: the stream is
        # start-sorted, so the init prefix is one searchsorted).
        init_end = init_n * delta
        self._cursor = 0
        self._passthrough(int(np.searchsorted(self._arr_t, init_end, side="left")))

    def _passthrough(self, k1: int) -> None:
        """Record arrivals [cursor, k1) into the controlled schedule
        verbatim (no admission control) and advance the cursor."""
        k0 = self._cursor
        if k1 <= k0:
            return
        rows = zip(
            self._arr_node[k0:k1].tolist(),
            self._arr_fn[k0:k1].tolist(),
            self._arr_t[k0:k1].tolist(),
            self._arr_dur[k0:k1].tolist(),
        )
        for node, fn, t, dur in rows:
            self._controlled[node].append((fn, t, dur))
        self._cursor = k1

    def attach_session(self, session) -> None:
        """Give the loop the live ``StreamingFleetSession`` (retrain/resync
        act on it); called by ``profile_fleet`` once the session exists."""
        self.session = session

    # -- live footprints ----------------------------------------------------

    def _footprint_of(self, fn_name: str) -> float | None:
        """Fleet-mean live per-invocation footprint J_lambda (J), or None
        before any node has metered an invocation of this function."""
        j = self.registry.index[fn_name]
        vals = [
            tr.per_invocation_indiv[j]
            for tr in self.trackers
            if tr is not None and tr.invocations[j] > 0
        ]
        return float(np.mean(vals)) if vals else None

    # -- the tick hook -------------------------------------------------------

    def on_tick(self, tk, trackers) -> None:
        """One control round: observe -> bill -> admit/place -> maintain."""
        if not self._bound:
            raise ValueError("ControlLoop.on_tick before bind()")
        cfg = self.config
        self.ticks_seen += 1
        now = tk.t * self.delta
        live = tk.valid
        # (1) capping observes each node's sensed power, plus the load the
        # loop itself shifted into this window (deferred work re-injected
        # later than the baseline ran it — invisible to the observed
        # telemetry, so it is charged at nameplate on top).
        self._shifted = [(n, e, p) for (n, e, p) in self._shifted if e > now]
        shifted = np.zeros(self.b)
        for n, _, p in self._shifted:
            shifted[n] += p
        self.fleet.observe_power(np.asarray(tk.w_sys, float) + shifted, valid=live)
        # (2) pricing folds the conserved per-tick attribution in.
        for i in range(self.b):
            if live is None or live[i]:
                self.meter.observe_tick(
                    tk.tick_power[i], tk.a[i], self.delta, idle_watts=self.idle[i]
                )
        # (3) admission + placement for this window's arrivals.  The stream
        # is start-sorted, so this window's slice is one searchsorted — the
        # per-arrival Python scan over the cursor scaled as O(ticks + N)
        # comparisons *inside the tick hook*; the bulk build keeps the hot
        # path a few numpy calls.  Submission order (arrival order) is
        # preserved, so admission decisions are exactly the loop's.
        wend = now + self.delta
        names = self.registry.names
        k0 = self._cursor
        k1 = int(np.searchsorted(self._arr_t, wend, side="left"))
        if k1 > k0:
            arr_fn = self._arr_fn[k0:k1].tolist()
            arr_t = self._arr_t[k0:k1].tolist()
            arr_dur = self._arr_dur[k0:k1].tolist()
            arr_node = self._arr_node[k0:k1].tolist()
            for fn, t, dur, node in zip(arr_fn, arr_t, arr_dur, arr_node):
                self.scheduler.submit(
                    Invocation(
                        function=names[fn],
                        arrival=t,
                        payload={"node": node, "dur": dur, "fn": fn},
                    )
                )
            self._cursor = k1
        placed = self.scheduler.drain_fleet(
            now, fleet=self.fleet, placement=cfg.placement, live=live
        )
        for inv, node in placed:
            fn = inv.payload["fn"]
            self._controlled[node].append(
                (fn, float(inv.started_at), inv.payload["dur"])
            )
            # A deferred restart (or a migration) runs power the baseline
            # telemetry never saw on this node: self-charge it.
            if inv.started_at > inv.arrival + 1e-9 or node != inv.payload["node"]:
                self._shifted.append(
                    (
                        node,
                        float(inv.started_at) + inv.payload["dur"],
                        float(self._nameplate[fn]),
                    )
                )
        # (4) model maintenance at step boundaries.
        if tk.step_completed and self.session is not None:
            if cfg.retrain and bool(self.session.retrain_needed.any()):
                flags = self.session.refit_counter_models(
                    self.session.retrain_needed,
                    window_steps=cfg.retrain_window_steps,
                )
                if flags.any():
                    self.retrain_events.append((tk.t, flags))
            if cfg.resync_every_steps:
                steps = len(self.session.model_errors) or (
                    (tk.t + 1 - self.init_n) // self.session.cfg.step_windows
                )
                if steps and steps % cfg.resync_every_steps == 0:
                    self.session.resync()
                    self.resync_events.append(tk.t)

    # -- completion ----------------------------------------------------------

    def finish(self) -> None:
        """Close the loop after the replay: pass the post-engine tail
        through verbatim, then drain the still-deferred queue past the
        segment end with footprint-aware packing — windows are filled up to
        the cap using each invocation's predicted power (J_lambda / tau),
        advancing one control window at a time, so the deferred work lands
        as a cap-respecting tail instead of one spike."""
        if self._finished:
            return
        self._finished = True
        cfg = self.config
        # Tail arrivals the engine never saw: uncontrolled passthrough.
        self._passthrough(self._arr_t.size)
        # Deferred leftovers: predictive packing after the last real window.
        last = max(
            [self.n_used * self.delta]
            + [s + 0.0 for node in self._controlled for (_, s, _) in node[-1:]]
        )
        w = int(np.ceil(max(last, self.orig_duration) / self.delta))
        # Seed the packer with everything already scheduled that is still
        # running at the first drain window (live-region admissions whose
        # durations cross the segment boundary) — an empty start would let
        # the packer stack drained work on top of them.
        running: list[tuple[int, float, float]] = [  # (node, end_t, watts)
            (i, s + d, float(self._nameplate[fn]))
            for i, node in enumerate(self._controlled)
            for (fn, s, d) in node
            if s + d > w * self.delta
        ]
        specs = self.registry.specs
        pack_cap = cfg.cap_watts * (1.0 - cfg.drain_margin)
        while self.scheduler.queue:
            inv = self.scheduler.queue.popleft()
            fn = inv.payload["fn"]
            dur = max(inv.payload["dur"], 1e-3)
            j = self._footprint_of(inv.function)
            # Measured footprints are *attributed* watts — at high
            # concurrency the host's sublinear power curve compresses each
            # invocation's share, so J_lambda / tau under-predicts what the
            # same invocation draws in the (less concurrent) drain tail.
            # Pack against the larger of the measured rate and the
            # registry's nameplate dynamic power: conservative in either
            # direction, so drain windows land under the cap.
            watts = max(
                (j / dur) if j is not None else 0.0, specs[fn].dyn_power_w
            )
            while True:
                now = w * self.delta
                running = [r for r in running if r[1] > now]
                loads = self.idle.copy()
                for node, _, p in running:
                    loads[node] += p
                # No-migration mode drains each leftover on its origin node.
                order = (
                    np.argsort(loads, kind="stable")
                    if cfg.placement
                    else [inv.payload["node"]]
                )
                placed = False
                for i in order:
                    i = int(i)
                    # An idle node always admits (termination + conservation:
                    # deferred work must run even if one invocation alone
                    # exceeds the cap).
                    if loads[i] + watts <= pack_cap or loads[i] <= self.idle[i] + 1e-9:
                        self._controlled[i].append((fn, now, dur))
                        running.append((i, now + dur, watts))
                        self.drain_waits.append(now - inv.arrival)
                        placed = True
                        break
                if placed:
                    break
                w += 1

    def controlled_traces(self) -> list[InvocationTrace]:
        """The reshaped per-node traces: every original invocation, same
        durations, starts moved by admission control.  Re-simulate these to
        measure what the control actually did to power."""
        if not self._finished:
            raise ValueError("controlled_traces needs finish() (profile_fleet calls it)")
        end_max = self.orig_duration
        for node in self._controlled:
            for _, s, d in node:
                end_max = max(end_max, s + d)
        duration = float(np.ceil(end_max / self.delta) * self.delta)
        names = self.registry.names
        out = []
        for node in self._controlled:
            if node:
                fn = np.asarray([e[0] for e in node], np.int32)
                st = np.asarray([e[1] for e in node], np.float64)
                du = np.asarray([e[2] for e in node], np.float64)
            else:
                fn = np.zeros(0, np.int32)
                st = np.zeros(0)
                du = np.zeros(0)
            order = np.argsort(st, kind="stable")
            out.append(
                InvocationTrace(
                    fn_id=fn[order],
                    start=st[order].astype(np.float32),
                    end=(st + du)[order].astype(np.float32),
                    num_fns=self.num_fns,
                    duration=duration,
                    fn_names=names,
                )
            )
        return out

    def summary(self) -> dict:
        """Scalar outcome metrics: capping, deferral cost, maintenance."""
        stats = self.fleet.stats
        waits = np.asarray(self.scheduler.stats.queue_waits + self.drain_waits)
        return {
            "ticks": self.ticks_seen,
            "observed_overshoot_fraction": stats.overshoot_fraction,
            "admitted": stats.admitted,
            "deferred_decisions": stats.deferred,
            "deferred_by_cap": self.scheduler.stats.deferred_by_cap,
            "mean_queue_wait_s": float(waits.mean()) if waits.size else 0.0,
            "max_queue_wait_s": float(waits.max()) if waits.size else 0.0,
            "billed_joules": float(np.sum(self.meter.j_total)),
            "retrain_events": len(self.retrain_events),
            "resync_events": len(self.resync_events),
        }


class EnergyFirstControlPlane:
    """Single-node energy-first control plane over a function registry."""

    def __init__(
        self,
        registry: FunctionRegistry,
        sim_config: SimulatorConfig = SimulatorConfig(),
        profiler_config: ProfilerConfig = ProfilerConfig(),
        pricing_config: PricingConfig = PricingConfig(),
    ):
        self.registry = registry
        self.simulator = NodeSimulator(registry, sim_config)
        self.profiler = FaasMeterProfiler(profiler_config)
        self.pricing = pricing_config

    # -- profiling ---------------------------------------------------------

    def profile_trace(self, trace: InvocationTrace, *, seed: int | None = None) -> ProfiledWorkload:
        sim = self.simulator.simulate(trace, seed=seed)
        report = self.profiler.profile(
            jnp.asarray(trace.fn_id),
            jnp.asarray(trace.start),
            jnp.asarray(trace.end),
            num_fns=trace.num_fns,
            duration=trace.duration,
            telemetry=sim.telemetry,
        )
        mem = jnp.asarray([s.mem_gb for s in self.registry.specs], jnp.float32)
        prices = price_report(
            report.spectrum.j_indiv,
            report.spectrum.j_total,
            report.invocations,
            report.mean_latency,
            mem,
            self.pricing,
        )
        return ProfiledWorkload(report=report, sim=sim, trace=trace, prices=prices)

    def combined_counter_inputs(
        self,
        profiler: FaasMeterProfiler,
        trace_arrays,
        telemetries,
        *,
        num_fns: int,
        duration,
    ):
        """Counter features + per-node ridge models for combined mode (§4.3).

        Derives the (M,) step-counter specs (gflops/hbm/mean latency) from
        the registry and delegates to ``core.profiler.prepare_combined_fleet``
        — models are fit on each node's N_init block of chip power, so the
        same inputs drive the batch, streaming, and per-node-oracle paths
        identically.  Returns ``(fn_counters, window_features, models)``.
        """
        specs = self.registry.specs
        return prepare_combined_fleet(
            profiler.config, trace_arrays, telemetries,
            num_fns=num_fns, duration=duration,
            gflops=np.asarray([s.gflops for s in specs]),
            hbm_gb=np.asarray([s.hbm_gb for s in specs]),
            mean_latency=np.asarray(
                [max(s.mean_latency_s, 1e-3) for s in specs]
            ),
        )

    def profile_fleet(
        self,
        traces: list[InvocationTrace],
        *,
        seeds: list[int] | None = None,
        platforms: list[str] | None = None,
        on_tick=None,
        mesh="auto",
        slots: int | None = None,
        mode: str | None = None,
        prefetch: int = 2,
        drain: bool = False,
        control: "ControlLoop | None" = None,
        tick_transform=None,
    ) -> list[ProfiledWorkload]:
        """Profile many nodes through the *streaming* fleet engine, live.

        One vectorized simulation pass generates every node's power traces;
        the telemetry is then replayed into a ``StreamingFleetSession`` one
        delta-window at a time, exactly as a live collection pipeline would
        deliver it.  Each engine tick feeds every node's
        ``StreamingFootprintTracker`` (``observe_tick``) — per-invocation
        footprints are current *during* the segment, not reconstructed from
        a finished one — and then calls ``on_tick(stream_tick, trackers)``,
        the online pricing/capping hook (see examples/stream_energy.py).

        Falls back to the per-node path (no trackers) when the segment is
        too short for a single Kalman step.

        Ragged fleets are first-class: traces may have different
        ``duration``s (nodes joining a metering segment late or leaving it
        early).  The simulator, the streaming session, and the engine all
        mask the ended nodes out (``FleetStep.valid``), live trackers stop
        accumulating the moment their node's stream ends, and each node's
        report covers exactly its own span.  Only when some node is too
        short to bootstrap (no common N_init window) — or no node reaches
        a full Kalman step — does the fleet drop to the per-node path.

        Args:
          traces: per-node invocation traces (equal num_fns; durations may
            differ).
          seeds: optional per-node simulator seeds.
          platforms: optional per-node platform names
            (``"server"``/``"desktop"``/``"edge"``) — a heterogeneous fleet
            runs as ONE batch, the per-node power-model parameters stacked
            as data through the simulator and the engines.  ``None`` uses
            the simulator's own configuration for every node.
          on_tick: optional hook ``(core.profiler.StreamTick,
            list[StreamingFootprintTracker]) -> None`` run per engine tick.
          mesh: ``"auto"`` (default) builds a ``FleetMesh`` over the node
            axis when more than one device is visible and the fleet tiles
            onto them (``distributed.sharding.fleet_mesh_auto``), so a
            multi-device controller shards transparently; pass an explicit
            ``FleetMesh`` to pin the layout or ``None`` to force the
            single-device path.
          slots: optional slot-pool capacity — when set, the session runs
            on a ``core.profiler.SlotFleetSession`` of this many slots
            (must be >= the fleet size).  Nodes claim slots at bootstrap
            and release them as their streams end, spare slots stay masked
            invalid, and the ``"auto"`` mesh is built over the *capacity*
            so elastic fleets shard without retracing.  Numerics match the
            plain session at 1e-5.
          mode: ``"pure"`` | ``"combined"`` (§4.3) — defaults to the
            profiler config's mode.  Combined needs chip telemetry on at
            least one node; per-node counter models are fit on the N_init
            block (``combined_counter_inputs``), the engines disaggregate
            the chip-subtracted 'rest' power, live trackers are fed the
            full X = X_CPU + X_Rest, and retrain flags are checked at
            every Kalman step (``session.retrain_needed``).  Chipless
            nodes (the edge platform) ride the same batch as data: their
            chip series is identically zero and their counter model is
            the zero model, which makes the combined target degenerate to
            the pure one on those rows exactly — no per-node branches.
          prefetch: ingest lookahead — ticks are pulled on a background
            thread this many windows ahead of the engine
            (``StreamingFleetSession.ingest``), overlapping host-side
            telemetry work with the jitted ``fleet_step``; ``0`` forces
            strict sense/step alternation.
          drain: run the emit stage (attribution materialization, retrain
            checks, tick hooks — including the bound ``control`` loop) on
            a background drain thread as well, overlapping it with both
            ingest and the jitted step.  Dispatch order is unchanged, so
            results are bitwise identical to ``drain=False``.
          control: optional ``ControlLoop`` — the closed-loop controller.
            It is bound to this replay (arrival stream, trackers, idle
            floors), hooked into the tick path *after* trackers update and
            *before* ``on_tick``, and finished after ``finalize`` (its
            ``controlled_traces()`` then hold the reshaped schedule).
            Requires the streaming path: a segment too short to stream
            raises instead of silently skipping control.
          tick_transform: optional ``iterator -> iterator`` over the
            ``FleetTelemetryTick`` stream, applied before ingest — the
            fault/drift-injection hook (``simulator.chip_drift_transform``
            feeds the retrain-recovery tests and benchmark).

        Returns:
          One ``ProfiledWorkload`` per node, with ``footprint_stream``
          holding the live-fed tracker (None on the short-segment fallback).
        """
        if not traces:
            return []
        if isinstance(mesh, str):
            if mesh != "auto":
                raise ValueError(f"mesh must be 'auto', None, or a FleetMesh; got {mesh!r}")
            from repro.distributed.sharding import fleet_mesh_auto

            mesh = fleet_mesh_auto(len(traces) if slots is None else slots)
        cfg = self.profiler.config
        mode = cfg.mode if mode is None else mode
        if mode not in ("pure", "combined"):
            raise ValueError(f"mode must be 'pure' or 'combined'; got {mode!r}")
        profiler = (
            self.profiler
            if mode == cfg.mode
            else FaasMeterProfiler(dataclasses.replace(cfg, mode=mode))
        )
        cfg = profiler.config
        combined = mode == "combined"
        sims = self.simulator.simulate_fleet(traces, seeds, platforms=platforms)
        durations = [t.duration for t in traces]
        ragged = len(set(durations)) > 1
        duration = durations if ragged else durations[0]
        num_fns = traces[0].num_fns
        trace_arrays = [
            (jnp.asarray(t.fn_id), jnp.asarray(t.start), jnp.asarray(t.end))
            for t in traces
        ]
        tels = [s.telemetry for s in sims]
        has_chip = [tel.chip_power is not None for tel in tels]
        if combined and not any(has_chip):
            raise ValueError(
                "profile_fleet(mode='combined') needs a chip power source "
                "on at least one node (no platform here has one — use pure "
                "mode)"
            )
        plans = [segment_plan(cfg, d) for d in durations]
        n_max = max(p[0] for p in plans)
        s = max(p[2] for p in plans)
        init_uniform = len({p[1] for p in plans}) == 1
        has_cp_flags = [
            cfg.account_control_plane and tel.cp_cpu_frac is not None for tel in tels
        ]
        if len(set(has_cp_flags)) > 1:
            raise ValueError(
                "profile_fleet needs a homogeneous fleet: telemetries mix "
                "present/absent cp_cpu_frac (use fleet_profile instead)"
            )
        fn_counters = window_feats = counter_model = None
        if combined and init_uniform:
            fn_counters, window_feats, counter_model = self.combined_counter_inputs(
                profiler, trace_arrays, tels, num_fns=num_fns, duration=duration
            )

        if s == 0 or not init_uniform:
            # Too short for any Kalman step (or some node cannot even cover
            # the common init window): no streaming state to track.  An
            # attached-but-never-fed tracker would report 0 J/invocation
            # as if it were a measurement, so footprint_stream stays None.
            if control is not None:
                raise ValueError(
                    "profile_fleet(control=...) needs the streaming path: "
                    "the segment is too short for a Kalman step (or nodes "
                    "cannot cover a common N_init window), so there is no "
                    "tick stream to drive the control loop"
                )
            if combined and not init_uniform:
                raise ValueError(
                    "profile_fleet(mode='combined') needs every node to "
                    "cover the common N_init window (counter models are "
                    "fit on it); use the per-node path"
                )
            reports = fleet_profile(
                profiler, trace_arrays, tels,
                num_fns=num_fns, duration=duration,
                fn_counters=fn_counters, counter_model=counter_model,
            )
            trackers: list[StreamingFootprintTracker | None] = [None] * len(traces)
        else:
            trackers = [
                StreamingFootprintTracker(num_fns, idle_watts=tel.idle_watts)
                for tel in tels
            ]
            if control is not None:
                control.bind(
                    traces=traces, registry=self.registry, trackers=trackers,
                    idle_watts=[tel.idle_watts for tel in tels],
                    delta=cfg.delta, init_n=plans[0][1],
                    n_used=plans[0][1] + s * cfg.step_windows,
                )

            # Combined mode: live trackers meter the full spectrum — the
            # causal rest estimate plus the node's X_CPU.  X_CPU is static
            # per segment *until* a live refit swaps counter models
            # (ControlLoop retrain), so the numpy snapshot is re-pulled
            # whenever the session's refit count moves.
            _x_cpu_cache: dict = {"refits": -1, "v": None}

            def _x_cpu_now():
                n = len(session.refits)
                if _x_cpu_cache["refits"] != n:
                    _x_cpu_cache["v"] = np.asarray(session.x_cpu)
                    _x_cpu_cache["refits"] = n
                return _x_cpu_cache["v"]

            def _full_x(x_rest, i):
                if not combined:
                    return x_rest
                return np.asarray(x_rest[:num_fns]) + _x_cpu_now()[i]

            def _on_bootstrap(sess):
                # Seed with the init segment (X_0 estimate) so functions
                # active only early still carry their energy.
                for i, tr in enumerate(trackers):
                    tr.observe_step(
                        _full_x(np.asarray(sess.x0[i]), i),
                        np.asarray(sess.init_busy_seconds[i]),
                        np.asarray(sess.init_invocations[i]),
                        sess.init_seconds,
                    )

            def _on_tick(tk):
                for i, tr in enumerate(trackers):
                    # Ragged fleet: a node whose stream has ended stops
                    # accumulating (its engine state is frozen; folding the
                    # dead ticks in would keep growing its idle share).
                    if tk.valid is None or tk.valid[i]:
                        tr.observe_tick(
                            _full_x(tk.x[i], i), tk.busy_seconds[i], tk.a[i], cfg.delta
                        )
                if control is not None:
                    control.on_tick(tk, trackers)
                if on_tick is not None:
                    on_tick(tk, trackers)

            session = profiler.start_fleet_stream(
                trace_arrays, num_fns=num_fns, duration=duration,
                idle_watts=[tel.idle_watts for tel in tels],
                has_chip=has_chip,
                has_cp=has_cp_flags[0],
                on_tick=_on_tick, on_bootstrap=_on_bootstrap,
                mesh=mesh, slots=slots,
                fn_counters=fn_counters, counter_model=counter_model,
                window_features=window_feats,
            )
            # Stack each signal once into (N_max, B) so the tick generator
            # indexes rows instead of doing B Python-level scalar reads per
            # window; nodes shorter than the longest are zero-padded (the
            # session masks their dead ticks out of the engine anyway).
            def _stack(get):
                arr = np.zeros((n_max, len(tels)), np.float32)
                for i, tel in enumerate(tels):
                    col = get(tel)
                    if col is None:
                        continue  # chipless node: zero column, as data
                    col = np.asarray(col)
                    arr[: col.shape[0], i] = col
                return arr

            sys_np = _stack(lambda tel: tel.system_power)
            chip_np = _stack(lambda tel: tel.chip_power) if any(has_chip) else None
            cp_np = (
                _stack(lambda tel: tel.cp_cpu_frac) if has_cp_flags[0] else None
            )
            sf_np = (
                _stack(lambda tel: tel.sys_cpu_frac) if has_cp_flags[0] else None
            )

            def _ticks():
                for t in range(n_max):
                    yield FleetTelemetryTick(
                        t=t,
                        w_sys=sys_np[t],
                        w_chip=chip_np[t] if chip_np is not None else None,
                        cp_frac=cp_np[t] if cp_np is not None else None,
                        sys_frac=sf_np[t] if sf_np is not None else None,
                    )

            if control is not None:
                control.attach_session(session)
            ticks = _ticks()
            if tick_transform is not None:
                ticks = tick_transform(ticks)
            # The ingest stage pulls ticks on a background thread so window
            # t + 1's host work overlaps the engine's jitted step on t;
            # drain=True additionally moves tick emission off this thread.
            session.ingest(ticks, prefetch=prefetch, drain=drain)
            reports = session.finalize()
            if control is not None:
                control.finish()

        mem = jnp.asarray([sp.mem_gb for sp in self.registry.specs], jnp.float32)
        out = []
        for trace, sim, report, tracker in zip(traces, sims, reports, trackers):
            prices = price_report(
                report.spectrum.j_indiv,
                report.spectrum.j_total,
                report.invocations,
                report.mean_latency,
                mem,
                self.pricing,
            )
            out.append(
                ProfiledWorkload(
                    report=report, sim=sim, trace=trace, prices=prices,
                    footprint_stream=tracker,
                )
            )
        return out

    def marginal_energy(self, trace: InvocationTrace, fn: int, *, seed: int | None = None) -> float:
        """Paper Eq. 6 ground truth via the measured (coarse) energy totals."""
        return self.simulator.marginal_energy(trace, fn, seed=seed)

    # -- software power capping (Fig. 10) -----------------------------------

    def run_capped(
        self,
        trace: InvocationTrace,
        cap_watts: float,
        *,
        footprints: np.ndarray | None = None,
        control_dt: float = 0.25,
        use_footprints: bool = True,
    ) -> "CapRunResult":
        """Discrete-event execution of ``trace`` under a power cap.

        Invocations arrive at their trace start times; a deferred invocation
        keeps its *duration* but starts late (queue wait), exactly like the
        paper's queue-based software capping.
        """
        cfg = self.simulator.power_cfg
        model = self.simulator.model
        order = np.argsort(trace.start, kind="stable")
        valid = trace.fn_id[order] >= 0
        arr_fn = trace.fn_id[order][valid]
        arr_t = trace.start[order][valid]
        durs = (trace.end - trace.start)[order][valid]

        ctl = PowerCapController(
            CappingConfig(
                power_cap_watts=cap_watts,
                control_interval_s=control_dt,
                use_footprints=use_footprints,
            )
        )
        if footprints is None:
            footprints = np.asarray(
                [s.dyn_power_w * s.mean_latency_s for s in self.registry.specs]
            )
        # The controller knows class-mean latencies (FaasMeter telemetry),
        # never an invocation's realized duration.
        mean_lat = np.asarray([s.mean_latency_s for s in self.registry.specs])
        # Admission floor: at delta = 1 s windows, sub-window functions'
        # per-class power is under-resolved, but the AGGREGATE active power
        # is pinned by the efficiency property (sum C X ~ W - idle).  Floor
        # every class's admission increment at the fleet-average active
        # power X_bar = sum(J_i A_i) / sum(tau_i A_i) — conservative for
        # short functions, exact in aggregate.
        inv_counts = np.asarray(
            [max((trace.fn_id == j).sum(), 0) for j in range(trace.num_fns)], float
        )
        busy = float(np.sum(mean_lat * inv_counts))
        xbar = float(np.sum(footprints * inv_counts)) / max(busy, 1e-9)
        adm_footprints = np.maximum(footprints, xbar * mean_lat)

        n_steps = int(np.ceil(trace.duration / control_dt)) + 1
        running: list[tuple[int, float]] = []  # (fn, end_time)
        queue: deque[tuple[int, float, float]] = deque()  # (fn, dur, arrival)
        next_arrival = 0
        power_series = np.zeros(n_steps)
        new_start = np.full(arr_fn.shape, np.nan)
        new_fn = arr_fn.copy()
        new_dur = durs.copy()
        started = 0
        idx_of_started: list[int] = []

        for step in range(n_steps):
            now = step * control_dt
            # arrivals
            while next_arrival < len(arr_t) and arr_t[next_arrival] <= now:
                queue.append((arr_fn[next_arrival], durs[next_arrival], arr_t[next_arrival]))
                idx_of_started.append(next_arrival)
                next_arrival += 1
            # completions
            running = [(f, e) for (f, e) in running if e > now]
            # current power
            act = np.zeros(trace.num_fns)
            for f, _ in running:
                act[f] += 1.0
            p_dyn = float(model._compress(act @ model.dyn_power_w))
            watts = cfg.idle_w + p_dyn + cfg.cp_base_w
            power_series[step] = watts
            ctl.observe_power(watts)
            # admissions (head-of-queue, footprint-aware)
            while queue:
                f, dur, arr = queue[0]
                j = float(adm_footprints[f]) if use_footprints else None
                if not ctl.admit(j, duration_s=float(mean_lat[f])):
                    break
                queue.popleft()
                running.append((f, now + dur))
                # find the original slot for this (fn, arrival) pair
                k = started
                new_start[k] = now
                new_fn[k] = f
                new_dur[k] = dur
                started += 1
        # anything never started runs at the end (drain)
        for f, dur, arr in queue:
            new_start[started] = trace.duration
            new_fn[started] = f
            new_dur[started] = dur
            started += 1

        waits = new_start[:started] - arr_t[:started]
        return CapRunResult(
            power_series=power_series,
            control_dt=control_dt,
            cap_watts=cap_watts,
            stats=ctl.stats,
            queue_waits=np.maximum(waits, 0.0),
            latencies=new_dur[:started] + np.maximum(waits, 0.0),
        )


@dataclasses.dataclass
class CapRunResult:
    """Outcome of one capped discrete-event run (``run_capped``): the
    control-interval power series plus queue-wait/latency distributions."""

    power_series: np.ndarray
    control_dt: float
    cap_watts: float
    stats: object
    queue_waits: np.ndarray
    latencies: np.ndarray

    @property
    def overshoot_fraction(self) -> float:
        return float(np.mean(self.power_series > self.cap_watts))

    @property
    def mean_overshoot_magnitude(self) -> float:
        over = np.maximum(self.power_series - self.cap_watts, 0.0) / self.cap_watts
        violating = over[over > 0]
        return float(violating.mean()) if violating.size else 0.0


# ---------------------------------------------------------------------------
# Real-execution metered server
# ---------------------------------------------------------------------------


class MeteredServer:
    """Serve real (reduced) models and meter them through FaasMeter.

    Each registered (name, engine, batch) is a FaaS function class; ``serve``
    executes a request schedule, collects the *measured* invocation trace,
    and profiles it — the full energy-first serving path on live compute.
    """

    def __init__(self, profiler_config: ProfilerConfig | None = None):
        self.functions: dict[str, tuple] = {}
        self.order: list[str] = []
        self.profiler_config = profiler_config

    def register(self, name: str, engine, batch: dict, *, steps: int = 4) -> None:
        self.functions[name] = (engine, batch, steps)
        self.order.append(name)

    def serve(self, schedule: list[tuple[str, float]], duration: float):
        """Run (function, at_time) requests back-to-back; wall-clock metered.

        Returns an InvocationTrace in *relative* time with real latencies.
        """
        import time

        t_base = time.perf_counter()
        fn_ids, starts, ends = [], [], []
        for name, _at in schedule:
            engine, batch, steps = self.functions[name]
            if engine.cold:
                engine.warmup(batch)  # cold start, not metered as warm
            t0 = time.perf_counter() - t_base
            engine.generate(batch, steps)
            t1 = time.perf_counter() - t_base
            fn_ids.append(self.order.index(name))
            starts.append(t0)
            ends.append(t1)
        total = max(duration, (ends[-1] if ends else 0.0) + 1.0)
        return InvocationTrace(
            fn_id=np.asarray(fn_ids, np.int32),
            start=np.asarray(starts, np.float32),
            end=np.asarray(ends, np.float32),
            num_fns=len(self.order),
            duration=float(np.ceil(total)),
            fn_names=list(self.order),
        )
