"""Energy-first FaaS control plane (paper Fig. 1, §5, §6.3).

Ties together workload -> execution -> telemetry -> FaasMeter profiling ->
footprints -> pricing/capping, in two execution substrates:

- ``EnergyFirstControlPlane.profile_trace``: trace-driven (invocations carry
  their latencies; power comes from the telemetry simulator).  All paper
  benchmarks run through this — the profiler sees only degraded signals.
- ``EnergyFirstControlPlane.run_capped``: discrete-event execution under a
  software power cap (paper Fig. 10): arrivals queue, the head of the queue
  is admitted iff ``W*t + J_lambda <= W_cap*t`` using live FaasMeter
  footprints, and deferred invocations wait — reproducing the cap/latency
  trade-off and the <3 % overshoot claim.
- ``MeteredServer`` (real-exec): actual jitted model invocations on this
  host, timed, traced, and profiled — the end-to-end serving driver.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.capping import CappingConfig, PowerCapController
from repro.core.pricing import PricingConfig, price_report
from repro.core.profiler import (
    FaasMeterProfiler,
    FootprintReport,
    ProfilerConfig,
    fleet_profile_batched,
)
from repro.telemetry.simulator import NodeSimulator, SimResult, SimulatorConfig
from repro.workload.functions import FunctionRegistry
from repro.workload.trace import InvocationTrace

import jax.numpy as jnp


@dataclasses.dataclass
class ProfiledWorkload:
    report: FootprintReport
    sim: SimResult
    trace: InvocationTrace
    prices: dict
    footprint_stream: "StreamingFootprintTracker | None" = None


class StreamingFootprintTracker:
    """Streaming per-invocation footprint state for one node.

    The seed recomputed the whole footprint spectrum from scratch whenever a
    caller wanted fresh per-invocation numbers.  This tracker instead folds
    each Kalman step's outputs in as they arrive — O(M) per step — so the
    control plane can serve per-invocation footprints (for pricing and
    capping admission) that are always current without any recomputation
    over history.
    """

    def __init__(self, num_fns: int, idle_watts: float = 0.0):
        self.num_fns = num_fns
        self.idle_watts = idle_watts
        self.j_indiv = np.zeros(num_fns)        # cumulative attributed joules
        self.invocations = np.zeros(num_fns)    # cumulative invocation counts
        self.elapsed_s = 0.0
        self.steps_seen = 0

    def observe_step(
        self,
        x_step: np.ndarray,       # (M,) per-function power estimate after the step
        busy_seconds: np.ndarray,  # (M,) per-function runtime within the step
        a_step: np.ndarray,       # (M,) invocations in the step
        step_seconds: float,
    ) -> None:
        """Fold one Kalman step into the running footprints."""
        self.j_indiv += np.asarray(busy_seconds[: self.num_fns], float) * np.asarray(
            x_step[: self.num_fns], float
        )
        self.invocations += np.asarray(a_step[: self.num_fns], float)
        self.elapsed_s += step_seconds
        self.steps_seen += 1

    @property
    def per_invocation_indiv(self) -> np.ndarray:
        """(M,) running J/invocation of function execution alone."""
        return np.where(
            self.invocations > 0, self.j_indiv / np.maximum(self.invocations, 1.0), 0.0
        )

    @property
    def per_invocation_total(self) -> np.ndarray:
        """(M,) running J/invocation including the even idle-energy share
        over currently-active functions (§4.4 static-resource policy)."""
        active = self.invocations > 0
        n_active = max(int(active.sum()), 1)
        idle_j = self.idle_watts * self.elapsed_s / n_active
        total = self.j_indiv + np.where(active, idle_j, 0.0)
        return np.where(active, total / np.maximum(self.invocations, 1.0), 0.0)


class EnergyFirstControlPlane:
    """Single-node energy-first control plane over a function registry."""

    def __init__(
        self,
        registry: FunctionRegistry,
        sim_config: SimulatorConfig = SimulatorConfig(),
        profiler_config: ProfilerConfig = ProfilerConfig(),
        pricing_config: PricingConfig = PricingConfig(),
    ):
        self.registry = registry
        self.simulator = NodeSimulator(registry, sim_config)
        self.profiler = FaasMeterProfiler(profiler_config)
        self.pricing = pricing_config

    # -- profiling ---------------------------------------------------------

    def profile_trace(self, trace: InvocationTrace, *, seed: int | None = None) -> ProfiledWorkload:
        sim = self.simulator.simulate(trace, seed=seed)
        report = self.profiler.profile(
            jnp.asarray(trace.fn_id),
            jnp.asarray(trace.start),
            jnp.asarray(trace.end),
            num_fns=trace.num_fns,
            duration=trace.duration,
            telemetry=sim.telemetry,
        )
        mem = jnp.asarray([s.mem_gb for s in self.registry.specs], jnp.float32)
        prices = price_report(
            report.spectrum.j_indiv,
            report.spectrum.j_total,
            report.invocations,
            report.mean_latency,
            mem,
            self.pricing,
        )
        return ProfiledWorkload(report=report, sim=sim, trace=trace, prices=prices)

    def profile_fleet(
        self, traces: list[InvocationTrace], *, seeds: list[int] | None = None
    ) -> list[ProfiledWorkload]:
        """Profile many nodes through the batched fleet engine.

        One vectorized simulation pass generates every node's power traces,
        one batched engine invocation disaggregates the whole fleet, and
        each node's Kalman steps are streamed into a
        ``StreamingFootprintTracker`` so per-invocation footprints update
        incrementally instead of being recomputed per request.
        """
        if not traces:
            return []
        sims = self.simulator.simulate_fleet(traces, seeds)
        duration = traces[0].duration
        num_fns = traces[0].num_fns
        trace_arrays = [
            (jnp.asarray(t.fn_id), jnp.asarray(t.start), jnp.asarray(t.end))
            for t in traces
        ]
        reports, extras = fleet_profile_batched(
            self.profiler,
            trace_arrays,
            [s.telemetry for s in sims],
            num_fns=num_fns,
            duration=duration,
            return_extras=True,
        )
        mem = jnp.asarray([s.mem_gb for s in self.registry.specs], jnp.float32)
        out = []
        step_seconds = self.profiler.config.step_windows * self.profiler.config.delta
        for i, (trace, sim, report) in enumerate(zip(traces, sims, reports)):
            # No tracker at all when the trace was too short for Kalman steps
            # (per-node fallback): an attached-but-never-fed tracker would
            # report 0 J/invocation as if it were a measurement.
            tracker = None
            if extras is not None:
                tracker = StreamingFootprintTracker(
                    num_fns, idle_watts=sim.telemetry.idle_watts
                )
                # Seed with the init segment (X_0 estimate) so functions
                # active only early still carry their energy...
                tracker.observe_step(
                    np.asarray(extras.result.x0[i]),
                    np.asarray(extras.init_busy_seconds[i]),
                    np.asarray(extras.init_invocations[i]),
                    extras.init_seconds,
                )
                # ...then stream each Kalman step's update.
                traj = np.asarray(extras.result.x_trajectory[i])
                busy = np.asarray(extras.inputs.c[i].sum(axis=1))  # (S, M_aug) s
                a_steps = np.asarray(extras.inputs.a[i])
                for j in range(traj.shape[0]):
                    tracker.observe_step(traj[j], busy[j], a_steps[j], step_seconds)
            prices = price_report(
                report.spectrum.j_indiv,
                report.spectrum.j_total,
                report.invocations,
                report.mean_latency,
                mem,
                self.pricing,
            )
            out.append(
                ProfiledWorkload(
                    report=report, sim=sim, trace=trace, prices=prices,
                    footprint_stream=tracker,
                )
            )
        return out

    def marginal_energy(self, trace: InvocationTrace, fn: int, *, seed: int | None = None) -> float:
        """Paper Eq. 6 ground truth via the measured (coarse) energy totals."""
        return self.simulator.marginal_energy(trace, fn, seed=seed)

    # -- software power capping (Fig. 10) -----------------------------------

    def run_capped(
        self,
        trace: InvocationTrace,
        cap_watts: float,
        *,
        footprints: np.ndarray | None = None,
        control_dt: float = 0.25,
        use_footprints: bool = True,
    ) -> "CapRunResult":
        """Discrete-event execution of ``trace`` under a power cap.

        Invocations arrive at their trace start times; a deferred invocation
        keeps its *duration* but starts late (queue wait), exactly like the
        paper's queue-based software capping.
        """
        cfg = self.simulator.power_cfg
        model = self.simulator.model
        order = np.argsort(trace.start, kind="stable")
        valid = trace.fn_id[order] >= 0
        arr_fn = trace.fn_id[order][valid]
        arr_t = trace.start[order][valid]
        durs = (trace.end - trace.start)[order][valid]

        ctl = PowerCapController(
            CappingConfig(
                power_cap_watts=cap_watts,
                control_interval_s=control_dt,
                use_footprints=use_footprints,
            )
        )
        if footprints is None:
            footprints = np.asarray(
                [s.dyn_power_w * s.mean_latency_s for s in self.registry.specs]
            )
        # The controller knows class-mean latencies (FaasMeter telemetry),
        # never an invocation's realized duration.
        mean_lat = np.asarray([s.mean_latency_s for s in self.registry.specs])
        # Admission floor: at delta = 1 s windows, sub-window functions'
        # per-class power is under-resolved, but the AGGREGATE active power
        # is pinned by the efficiency property (sum C X ~ W - idle).  Floor
        # every class's admission increment at the fleet-average active
        # power X_bar = sum(J_i A_i) / sum(tau_i A_i) — conservative for
        # short functions, exact in aggregate.
        inv_counts = np.asarray(
            [max((trace.fn_id == j).sum(), 0) for j in range(trace.num_fns)], float
        )
        busy = float(np.sum(mean_lat * inv_counts))
        xbar = float(np.sum(footprints * inv_counts)) / max(busy, 1e-9)
        adm_footprints = np.maximum(footprints, xbar * mean_lat)

        n_steps = int(np.ceil(trace.duration / control_dt)) + 1
        running: list[tuple[int, float]] = []  # (fn, end_time)
        queue: deque[tuple[int, float, float]] = deque()  # (fn, dur, arrival)
        next_arrival = 0
        power_series = np.zeros(n_steps)
        new_start = np.full(arr_fn.shape, np.nan)
        new_fn = arr_fn.copy()
        new_dur = durs.copy()
        started = 0
        idx_of_started: list[int] = []

        for step in range(n_steps):
            now = step * control_dt
            # arrivals
            while next_arrival < len(arr_t) and arr_t[next_arrival] <= now:
                queue.append((arr_fn[next_arrival], durs[next_arrival], arr_t[next_arrival]))
                idx_of_started.append(next_arrival)
                next_arrival += 1
            # completions
            running = [(f, e) for (f, e) in running if e > now]
            # current power
            act = np.zeros(trace.num_fns)
            for f, _ in running:
                act[f] += 1.0
            p_dyn = float(model._compress(act @ model.dyn_power_w))
            watts = cfg.idle_w + p_dyn + cfg.cp_base_w
            power_series[step] = watts
            ctl.observe_power(watts)
            # admissions (head-of-queue, footprint-aware)
            while queue:
                f, dur, arr = queue[0]
                j = float(adm_footprints[f]) if use_footprints else None
                if not ctl.admit(j, duration_s=float(mean_lat[f])):
                    break
                queue.popleft()
                running.append((f, now + dur))
                # find the original slot for this (fn, arrival) pair
                k = started
                new_start[k] = now
                new_fn[k] = f
                new_dur[k] = dur
                started += 1
        # anything never started runs at the end (drain)
        for f, dur, arr in queue:
            new_start[started] = trace.duration
            new_fn[started] = f
            new_dur[started] = dur
            started += 1

        waits = new_start[:started] - arr_t[:started]
        return CapRunResult(
            power_series=power_series,
            control_dt=control_dt,
            cap_watts=cap_watts,
            stats=ctl.stats,
            queue_waits=np.maximum(waits, 0.0),
            latencies=new_dur[:started] + np.maximum(waits, 0.0),
        )


@dataclasses.dataclass
class CapRunResult:
    power_series: np.ndarray
    control_dt: float
    cap_watts: float
    stats: object
    queue_waits: np.ndarray
    latencies: np.ndarray

    @property
    def overshoot_fraction(self) -> float:
        return float(np.mean(self.power_series > self.cap_watts))

    @property
    def mean_overshoot_magnitude(self) -> float:
        over = np.maximum(self.power_series - self.cap_watts, 0.0) / self.cap_watts
        violating = over[over > 0]
        return float(violating.mean()) if violating.size else 0.0


# ---------------------------------------------------------------------------
# Real-execution metered server
# ---------------------------------------------------------------------------


class MeteredServer:
    """Serve real (reduced) models and meter them through FaasMeter.

    Each registered (name, engine, batch) is a FaaS function class; ``serve``
    executes a request schedule, collects the *measured* invocation trace,
    and profiles it — the full energy-first serving path on live compute.
    """

    def __init__(self, profiler_config: ProfilerConfig | None = None):
        self.functions: dict[str, tuple] = {}
        self.order: list[str] = []
        self.profiler_config = profiler_config

    def register(self, name: str, engine, batch: dict, *, steps: int = 4) -> None:
        self.functions[name] = (engine, batch, steps)
        self.order.append(name)

    def serve(self, schedule: list[tuple[str, float]], duration: float):
        """Run (function, at_time) requests back-to-back; wall-clock metered.

        Returns an InvocationTrace in *relative* time with real latencies.
        """
        import time

        t_base = time.perf_counter()
        fn_ids, starts, ends = [], [], []
        for name, _at in schedule:
            engine, batch, steps = self.functions[name]
            if engine.cold:
                engine.warmup(batch)  # cold start, not metered as warm
            t0 = time.perf_counter() - t_base
            engine.generate(batch, steps)
            t1 = time.perf_counter() - t_base
            fn_ids.append(self.order.index(name))
            starts.append(t0)
            ends.append(t1)
        total = max(duration, (ends[-1] if ends else 0.0) + 1.0)
        return InvocationTrace(
            fn_id=np.asarray(fn_ids, np.int32),
            start=np.asarray(starts, np.float32),
            end=np.asarray(ends, np.float32),
            num_fns=len(self.order),
            duration=float(np.ceil(total)),
            fn_names=list(self.order),
        )
