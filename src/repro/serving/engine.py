"""Serve engine: jitted prefill/decode execution for one model instance.

One ``ServeEngine`` = one warm "sandbox" in FaaS terms: materialized params
plus compiled prefill/decode executables for a (batch, seq) bucket.  The
control plane keeps a keep-alive cache of engines (eviction = cold start on
next invocation) and meters every invocation through FaasMeter.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeConfig
from repro.models.model_zoo import ModelApi
from repro.serving.kv_cache import init_cache


@dataclasses.dataclass
class InvocationRecord:
    function: str
    start: float
    end: float
    kind: str          # prefill | decode | generate
    tokens: int = 0

    @property
    def latency(self) -> float:
        return self.end - self.start


class ServeEngine:
    """Compiled prefill + decode for one arch at one shape bucket."""

    def __init__(self, api: ModelApi, shape: ShapeConfig, params: Any, *, clock=time.perf_counter):
        self.api = api
        self.shape = shape
        self.params = params
        self.clock = clock
        self._prefill = jax.jit(api.prefill)
        self._decode = jax.jit(api.decode, donate_argnums=(1,))
        self.records: list[InvocationRecord] = []
        self.cold = True

    def warmup(self, batch: dict) -> None:
        """Cold start: trigger compilation (FaaS init overhead analogue)."""
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        self.cold = False
        self._warm_cache = cache

    def prefill(self, batch: dict, *, t0: float | None = None):
        start = self.clock() if t0 is None else t0
        logits, cache = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        end = self.clock()
        ntok = int(jnp.size(batch["tokens"]))
        self.records.append(InvocationRecord("prefill", start, end, "prefill", ntok))
        return logits, cache

    def generate(self, batch: dict, steps: int, *, greedy: bool = True):
        """Prefill then ``steps`` greedy decode steps.  Returns token matrix."""
        from repro.models.model_zoo import extend_cache

        start = self.clock()
        logits, cache = self._prefill(self.params, batch)
        cache = extend_cache(self.api, cache, steps)
        b = logits.shape[0]
        pos0 = batch["tokens"].shape[1]
        toks = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        for i in range(steps - 1):
            logits, cache = self._decode(
                self.params, cache, toks[-1][:, None], jnp.asarray(pos0 + i, jnp.int32)
            )
            toks.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        out = jnp.stack(toks, axis=1)
        jax.block_until_ready(out)
        end = self.clock()
        self.records.append(
            InvocationRecord("generate", start, end, "generate", int(b * steps))
        )
        return out

    def decode_step(self, cache, token, pos):
        start = self.clock()
        logits, cache = self._decode(self.params, cache, token, jnp.asarray(pos, jnp.int32))
        jax.block_until_ready(logits)
        end = self.clock()
        self.records.append(InvocationRecord("decode", start, end, "decode", logits.shape[0]))
        return logits, cache

    def fresh_cache(self):
        return init_cache(self.api, self.shape)
