"""Serving runtime: engines, scheduler, energy-first control plane."""
