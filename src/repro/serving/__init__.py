"""Serving runtime: engines, scheduler, energy-first control plane.

Top of the layer stack (see ``scripts/check_layering.py``): these modules
may import anything below — the profiler orchestration, the session layer,
the jitted engine stages — but nothing below may import them back.

``ServeEngine`` (model-zoo continuous batching) is intentionally not
re-exported here: it drags the full model zoo in at import time, while the
energy-first control plane is what this package exists for.
"""

from repro.serving.control_plane import (
    CapRunResult,
    ControlConfig,
    ControlLoop,
    EnergyFirstControlPlane,
    MeteredServer,
    ProfiledWorkload,
    StreamingFootprintTracker,
)
from repro.serving.scheduler import (
    EnergyAwareScheduler,
    Invocation,
    KeepAliveCache,
    SchedulerConfig,
    SchedulerStats,
    SlotAdmissionQueue,
    SlotRequest,
    energy_aware_placement,
)

__all__ = [
    "CapRunResult",
    "ControlConfig",
    "ControlLoop",
    "EnergyAwareScheduler",
    "EnergyFirstControlPlane",
    "Invocation",
    "KeepAliveCache",
    "MeteredServer",
    "ProfiledWorkload",
    "SchedulerConfig",
    "SchedulerStats",
    "SlotAdmissionQueue",
    "SlotRequest",
    "StreamingFootprintTracker",
    "energy_aware_placement",
]
