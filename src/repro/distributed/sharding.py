"""Sharding layers: logical-axis rules for models, fleet-axis mesh for the
fleet controller.

Two independent partitioning surfaces live here:

1. **Logical-axis rules** (MaxText-style, with divisibility fallback) for
   the model zoo — parameters/activations annotated with logical axes
   ("embed", "qkv", ...) mapped onto mesh axes by rule tables.
2. **Fleet-axis sharding** (:class:`FleetMesh`) for the FaasMeter fleet
   controller — the B-node axis of the batched/streaming disaggregation
   engines is sharded over a 1-D device mesh via ``shard_map``: per-node
   Kalman/disaggregation math runs entirely node-local (no collectives on
   the hot path) while fleet-level reductions
   (:func:`fleet_attribution_totals`) ``psum`` along the node axis.

Logical-axis rules (surface 1) in detail:

Parameters and activations are annotated with *logical* axes ("embed",
"qkv", "mlp", "vocab", "expert", "batch", "seq", "kv_heads", ...); rule
tables map logical axes onto mesh axes.  A mapping is applied only when

  1. the dimension is divisible by the product of the mesh-axis sizes, and
  2. none of those mesh axes is already used by another dimension of the
     same tensor (GSPMD requires each mesh axis at most once per spec).

Otherwise the dimension falls back along the rule's candidate chain and
ultimately to replication.  This is what lets one rule table cover all ten
assigned architectures (e.g. qwen2.5's 40 heads are not divisible by
model=16, but its flattened 40*128=5120 projection dim is).

Two built-in rule tables:

- ``TRAIN_RULES``: FSDP over "data" (weights' embed dim), TP over "model"
  (qkv/mlp/vocab/expert dims), batch over ("pod", "data"); gradients
  all-reduce over "pod" (pure DP across pods).
- ``SERVE_RULES``: weights TP over "model" and replicated over "data"
  (low-latency serving), batch over ("pod", "data"), KV cache batch-sharded
  with kv-heads on "model" when divisible (falls back to sequence).

Models call :func:`shard_activation` at block boundaries; it is a no-op
unless a rule context is active (set by the launchers via
:func:`use_rules`), keeping model code mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import math
import threading
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

# Each rule: logical axis -> tuple of candidate mesh-axis tuples, tried in
# order; () means replicate.
Rules = dict[str, tuple[tuple[str, ...], ...]]

TRAIN_RULES: Rules = {
    "batch": (("pod", "data"), ("data",), ()),
    "seq": ((),),
    "embed": (("data",), ()),          # FSDP shard of weight rows
    "act_embed": ((),),                # activations keep embed replicated
    "qkv": (("model",), ()),           # flattened heads*head_dim
    "heads": (("model",), ()),
    "kv_heads": (("model",), ()),
    "o_in": (("model",), ()),
    "mlp": (("model",), ()),
    "vocab": (("model",), ()),
    "lm_head": (("model",), ()),      # unembed output dim (logits vocab)
    "expert": (("model",), ()),
    "expert_mlp": ((),),
    "kv_seq": (("model",), ()),        # decode KV-cache sequence fallback
    "layers": ((),),
    "state": ((),),
    "conv": ((),),
    "cap": (("pod", "data"), ("data",), ()),  # MoE capacity slots
    "frontend": ((),),
}

SERVE_RULES: Rules = {
    **TRAIN_RULES,
    "batch": (("pod", "data"), ("data",), ()),
    "embed": ((),),                    # weights replicated over data for serve
    "kv_heads": (("model",), ()),
}

#: Expert-parallel-first variant (§Perf H-B3): the "model" axis is reserved
#: for experts; attention/shared-MLP weights drop TP (their per-layer
#: activation all-reduces vanish — they are small relative to expert FFNs
#: in fine-grained MoE), FSDP over "data" stays.
EP_RULES: Rules = {
    **TRAIN_RULES,
    "qkv": ((),),
    "heads": ((),),
    "kv_heads": ((),),
    "o_in": ((),),
    "mlp": ((),),
}

#: ZeRO-3 / pure-FSDP variant (§Perf H-A2): the "model" axis joins the batch
#: axis (TP degree 1) so per-layer TP activation all-reduces vanish; weights
#: shard their row dim over the combined (data x model) = 256-way axis and
#: are all-gathered per layer per pass.  Wins when activation-AR bytes
#: exceed weight-gather bytes (dense train at B_loc x S x d >> params/layer).
#: NOT for MoE archs: expert parallelism needs the "model" axis.
ZERO3_RULES: Rules = {
    **TRAIN_RULES,
    "batch": (("pod", "data", "model"), ("data", "model"), ("data",), ()),
    "embed": (("data", "model"), ("data",), ()),
    "qkv": ((),),
    "heads": ((),),
    "kv_heads": ((),),
    "o_in": ((),),
    "mlp": ((),),
    # vocab REPLICATED, embed-dim sharded: `take` gathers over a sharded
    # vocab dim force SPMD to replicate the whole table (measured: +6.3 GB
    # on nemotron's 256 k-vocab); with the embed dim sharded the lookup is
    # local and the (much smaller) activation gathers/psums do the work.
    # The unembed ("lm_head") stays vocab-sharded: it only feeds einsums,
    # and sharding it keeps logits AND the unembed gradient sharded
    # (replicated dW was +12.6 GB on nemotron).
    "vocab": ((),),
    "lm_head": (("data", "model"), ("model",), ()),
    "expert": ((),),
    "kv_seq": ((),),
}


_ctx = threading.local()


def _active() -> tuple[Mesh, Rules] | None:
    return getattr(_ctx, "active", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Rules):
    """Activate (mesh, rules) so model-internal ``shard_activation`` calls
    emit with_sharding_constraint; no-op outside the context."""
    prev = _active()
    _ctx.active = (mesh, rules)
    try:
        yield
    finally:
        _ctx.active = prev


def _mesh_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


#: When two dims of one tensor compete for the same mesh axis, the higher-
#: priority logical axis wins (e.g. a KV cache prefers kv_heads on "model",
#: falling back to kv_seq only when the head count is not divisible).
_PRIORITY = (
    "batch", "vocab", "lm_head", "expert", "qkv", "mlp", "kv_heads", "heads",
    "o_in", "embed", "kv_seq", "cap", "seq",
)
_PRIO = {name: i for i, name in enumerate(_PRIORITY)}


def spec_for(
    logical: Sequence[str | None], shape: Sequence[int], mesh: Mesh, rules: Rules
) -> P:
    """Resolve logical axes -> PartitionSpec under divisibility + axis-reuse
    constraints, visiting dims in logical-axis priority order."""
    used: set[str] = set()
    entries: list[Any] = [None] * len(logical)
    order = sorted(
        range(len(logical)),
        key=lambda i: _PRIO.get(logical[i], len(_PRIORITY)) if logical[i] else 1e9,
    )
    for i in order:
        name, dim = logical[i], shape[i]
        if name is None:
            continue
        for cand in rules.get(name, ((),)):
            if not cand:
                break
            if any(a in used for a in cand):
                continue
            if any(a not in mesh.shape for a in cand):
                continue
            if dim % _mesh_size(mesh, cand) != 0:
                continue
            entries[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
            break
    # Trim trailing Nones (canonical form).
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_for(
    logical: Sequence[str | None], shape: Sequence[int], mesh: Mesh, rules: Rules
) -> NamedSharding:
    """``spec_for`` wrapped into a concrete ``NamedSharding`` on ``mesh``."""
    return NamedSharding(mesh, spec_for(logical, shape, mesh, rules))


def tree_shardings(logical_tree: Any, abstract_tree: Any, mesh: Mesh, rules: Rules) -> Any:
    """Map a pytree of logical-axis tuples + ShapeDtypeStructs to shardings."""
    return jax.tree.map(
        lambda axes, a: sharding_for(axes, a.shape, mesh, rules),
        logical_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def shard_activation(x: Array, logical: Sequence[str | None]) -> Array:
    """Constrain an activation's sharding if a rule context is active."""
    active = _active()
    if active is None:
        return x
    mesh, rules = active
    spec = spec_for(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def abstract_with_sharding(abstract_tree: Any, logical_tree: Any, mesh: Mesh, rules: Rules) -> Any:
    """Attach shardings to ShapeDtypeStructs (dry-run input specs)."""
    return jax.tree.map(
        lambda a, axes: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=sharding_for(axes, a.shape, mesh, rules)
        ),
        abstract_tree,
        logical_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# ---------------------------------------------------------------------------
# Fleet-axis sharding: the B-node axis of the disaggregation engines over a
# 1-D device mesh (docs/architecture.md, "Sharded fleet").
# ---------------------------------------------------------------------------

#: Mesh-axis name of the fleet's node dimension.
FLEET_AXIS = "node"


@dataclasses.dataclass(frozen=True)
class FleetMesh:
    """A 1-D device mesh over the fleet's node (B) axis.

    Frozen and hashable so it can travel as a *static* jit argument — the
    streaming ``fleet_step`` keys its single trace on (config, mesh), which
    is what keeps the sharded stream at one compile for its whole lifetime.

    The node axis is the outermost dimension of every fleet array
    (``FleetInputs``, ``FleetStreamState`` buffers, ``FleetResult`` leaves);
    under this mesh each of the ``num_devices`` devices owns a contiguous
    ``B / num_devices`` block of nodes.  Per-node math needs no
    communication; fleet-level totals cross devices only through explicit
    ``psum`` (:func:`fleet_attribution_totals`).
    """

    mesh: Mesh
    axis: str = FLEET_AXIS

    @property
    def num_devices(self) -> int:
        """Devices along the node axis."""
        return self.mesh.shape[self.axis]

    def validate(self, num_nodes: int) -> None:
        """Reject fleets whose node count does not tile the mesh evenly."""
        if num_nodes % self.num_devices != 0:
            raise ValueError(
                f"fleet of {num_nodes} node(s) is not divisible by the "
                f"{self.num_devices}-device '{self.axis}' mesh; pad the fleet "
                f"or build the mesh with fleet_mesh(num_nodes={num_nodes})"
            )

    def node_sharding(self) -> NamedSharding:
        """Sharding that splits an array's leading axis over the nodes."""
        return NamedSharding(self.mesh, P(self.axis))

    def replicated_sharding(self) -> NamedSharding:
        """Sharding that replicates a leaf on every mesh device."""
        return NamedSharding(self.mesh, P())

    def put(self, tree: Any) -> Any:
        """Place a pytree on the mesh: leading axis sharded, scalars replicated.

        Every leaf with rank >= 1 is split over the node axis (its leading
        dimension must be divisible); rank-0 leaves (e.g. the streaming
        state's ``tick_in_step``/``step_idx`` counters) are replicated.
        Donated state placed this way stays sharded in place across
        ``fleet_step`` calls — no gather ever materializes the full fleet
        on one device.
        """

        def _place(leaf):
            arr = jnp.asarray(leaf)
            if arr.ndim == 0:
                return jax.device_put(arr, self.replicated_sharding())
            self.validate(arr.shape[0])
            return jax.device_put(arr, self.node_sharding())

        return jax.tree.map(_place, tree)

    def specs_like(self, tree: Any) -> Any:
        """Per-leaf ``PartitionSpec`` pytree: node-sharded unless rank-0."""
        node, rep = P(self.axis), P()
        return jax.tree.map(lambda l: rep if jnp.ndim(l) == 0 else node, tree)


def fleet_mesh(
    num_nodes: int | None = None,
    *,
    devices: Sequence[Any] | None = None,
    axis: str = FLEET_AXIS,
) -> FleetMesh:
    """Build a :class:`FleetMesh` from the available devices.

    With ``num_nodes`` given, the mesh uses the *largest* device count that
    divides the fleet evenly (so an awkward fleet size degrades to fewer
    devices instead of failing).  Works on a single device too — the 1-device
    mesh is the identity sharding, which is what lets every ``mesh=`` code
    path run (and be tested) without multi-device hardware.
    """
    import numpy as np

    devs = list(jax.devices() if devices is None else devices)
    d = len(devs)
    if num_nodes is not None:
        while d > 1 and num_nodes % d != 0:
            d -= 1
    return FleetMesh(mesh=Mesh(np.asarray(devs[:d]), (axis,)), axis=axis)


def fleet_mesh_auto(num_nodes: int) -> FleetMesh | None:
    """``fleet_mesh`` for controllers: None unless sharding actually helps.

    Returns a mesh only when more than one device is visible *and* the
    fleet divides onto more than one of them — the control plane's
    ``profile_fleet(mesh="auto")`` uses this so single-device deployments
    keep the exact unsharded code path.
    """
    if len(jax.devices()) <= 1:
        return None
    fm = fleet_mesh(num_nodes)
    return fm if fm.num_devices > 1 else None


def reshard(tree: Any, mesh: FleetMesh | None = None) -> Any:
    """Re-place a live pytree onto a (new) mesh mid-stream — mesh elasticity.

    The checkpoint-and-resume primitive for a device set that changes under
    a running stream (devices added, removed, or re-fitted into a different
    ``FleetMesh``): every leaf is pulled to host (``jax.device_get`` — the
    checkpoint barrier; safe on donated state, which the caller rebinds
    anyway) and re-placed with ``mesh.put`` — leading axes sharded over the
    new node axis, scalars replicated.  ``mesh=None`` re-places the state
    unsharded on the default device (scaling *down* to a single device).

    Values are bit-identical across the move; only the next ``fleet_step``
    trace changes (the mesh is a static jit arg), so a resharded stream is
    pinned at 1e-5 against an uninterrupted run — one deliberate compile
    per new mesh, never a per-tick retrace (tests/test_slot_serving.py).
    """
    host = jax.device_get(tree)
    if mesh is None:
        return jax.tree.map(jnp.asarray, host)
    return mesh.put(host)


class FleetTotals(NamedTuple):
    """Fleet-wide conserved-attribution totals (one controller-level view).

    ``per_fn.sum() + unattributed == attributed + unattributed`` equals the
    fleet's total measured active power-ticks: the per-tick efficiency
    property survives the cross-node reduction by linearity.

    Combined mode (§4.3) keeps the chip and 'rest' sides split all the way
    up: ``per_fn``/``attributed`` cover the disaggregated rest power, while
    ``chip_per_fn``/``chip_total`` aggregate the counter-model X_CPU (zeros
    when profiling pure mode) — a controller can bill the two spectra
    separately or sum them for full-spectrum totals.
    """

    per_fn: Array        # (M,) attributed power summed over nodes and ticks (W)
    attributed: Array    # ()   total attributed power-ticks across the fleet
    unattributed: Array  # ()   total unattributed power-ticks across the fleet
    cp_total: Array      # ()   control-plane power summed over nodes (0 if absent)
    chip_per_fn: Array   # (M,) counter-model chip power summed over nodes (W)
    chip_total: Array    # ()   fleet chip-side total (0 in pure mode)


def fleet_attribution_totals(
    tick_power: Array,            # (B, T, M) conserved per-tick power
    unattributed: Array,          # (B, T)
    cp_power: Array | None = None,  # (B,) per-node control-plane power estimate
    *,
    chip_power: Array | None = None,  # (B, M) per-node per-function X_CPU (§4.3)
    mask: Array | None = None,    # (B, T) tick validity for ragged fleets
    mesh: FleetMesh | None = None,
) -> FleetTotals:
    """Reduce per-node attribution to fleet totals (the ``psum`` path).

    Unsharded this is a handful of ``jnp.sum`` calls.  With a
    :class:`FleetMesh` the inputs stay sharded over the node axis: each
    device reduces its local node block and a single ``psum`` along the
    axis produces the replicated fleet totals — the only collective in the
    sharded controller (per-node Kalman/disaggregation math never
    communicates).

    ``chip_power`` is combined mode's (B, M) per-function chip-side power
    (``StreamingFleetSession.x_cpu`` / the counter-model split): it rides
    the same local-reduce + psum as the rest-side partials, keeping the
    §4.3 chip/rest split intact at fleet level (``chip_per_fn`` /
    ``chip_total``; zeros when absent).

    ``mask`` is the ragged fleet's ``(B, T)`` tick-validity mask
    (``FleetInputs.mask`` flattened over steps): padded ticks are excluded
    from every total *before* the reduction.  The masked engines already
    emit exactly-zero attribution on padded ticks, so for engine outputs
    the mask changes nothing — it exists so totals computed from any
    per-tick source (replayed logs, external meters) honor the same
    contract, and, sharded, it travels split over the node axis with the
    partials it masks (no device ever sees another shard's rag pattern).
    """
    cp = jnp.zeros((tick_power.shape[0],), tick_power.dtype) if cp_power is None else cp_power
    if mask is not None:
        mask = mask.reshape(unattributed.shape).astype(tick_power.dtype)

    def _local(tp, ua, cpv, m, chip):
        # Dense fleets (mask=None) keep the original plain-sum cost: no
        # ones-mask is ever materialized or multiplied through.
        if m is not None:
            tp = tp * m[..., None]
            ua = ua * m
        return _part(tp, ua, cpv, chip)

    if mesh is None:
        return _local(tick_power, unattributed, cp, mask, chip_power)
    mesh.validate(tick_power.shape[0])
    args = [tick_power, unattributed, cp]
    if mask is not None:
        args.append(mask)
    if chip_power is not None:
        args.append(chip_power)
    return _totals_runner(mesh, mask is not None, chip_power is not None)(*args)


def _part(tp, ua, cpv, chip) -> FleetTotals:
    """Node-local (single-shard) totals; ``chip=None`` fills zeros."""
    m = tp.shape[-1]
    return FleetTotals(
        per_fn=jnp.sum(tp, axis=(0, 1)),
        attributed=jnp.sum(tp),
        unattributed=jnp.sum(ua),
        cp_total=jnp.sum(cpv),
        chip_per_fn=(
            jnp.zeros((m,), tp.dtype) if chip is None else jnp.sum(chip, axis=0)
        ),
        chip_total=jnp.zeros((), tp.dtype) if chip is None else jnp.sum(chip),
    )


@functools.lru_cache(maxsize=None)
def _totals_runner(mesh: FleetMesh, has_mask: bool, has_chip: bool):
    """Compiled psum reduction for ``fleet_attribution_totals`` (cached per
    (mesh, has_mask, has_chip) so repeated controller ticks reuse one
    executable).  The ragged variant takes the tick mask as an extra
    input, the combined variant the (B, M) chip split — each sharded
    along the node axis like every other per-node array; the plain dense
    variant keeps the original three-input plain-sum program."""
    from repro.distributed.compat import shard_map

    node = P(mesh.axis)

    def _psum(part: FleetTotals) -> FleetTotals:
        return jax.tree.map(lambda v: jax.lax.psum(v, mesh.axis), part)

    def _local_psum(tp, ua, cpv, *rest):
        it = iter(rest)
        m = next(it) if has_mask else None
        chip = next(it) if has_chip else None
        if m is not None:
            tp = tp * m[..., None]
            ua = ua * m
        return _psum(_part(tp, ua, cpv, chip))

    in_specs = (node, node, node) + (node,) * (int(has_mask) + int(has_chip))

    return jax.jit(
        shard_map(
            _local_psum,
            mesh=mesh.mesh,
            in_specs=in_specs,
            out_specs=P(),
            check_vma=False,
        )
    )
