"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Parameters and activations are annotated with *logical* axes ("embed",
"qkv", "mlp", "vocab", "expert", "batch", "seq", "kv_heads", ...); rule
tables map logical axes onto mesh axes.  A mapping is applied only when

  1. the dimension is divisible by the product of the mesh-axis sizes, and
  2. none of those mesh axes is already used by another dimension of the
     same tensor (GSPMD requires each mesh axis at most once per spec).

Otherwise the dimension falls back along the rule's candidate chain and
ultimately to replication.  This is what lets one rule table cover all ten
assigned architectures (e.g. qwen2.5's 40 heads are not divisible by
model=16, but its flattened 40*128=5120 projection dim is).

Two built-in rule tables:

- ``TRAIN_RULES``: FSDP over "data" (weights' embed dim), TP over "model"
  (qkv/mlp/vocab/expert dims), batch over ("pod", "data"); gradients
  all-reduce over "pod" (pure DP across pods).
- ``SERVE_RULES``: weights TP over "model" and replicated over "data"
  (low-latency serving), batch over ("pod", "data"), KV cache batch-sharded
  with kv-heads on "model" when divisible (falls back to sequence).

Models call :func:`shard_activation` at block boundaries; it is a no-op
unless a rule context is active (set by the launchers via
:func:`use_rules`), keeping model code mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

# Each rule: logical axis -> tuple of candidate mesh-axis tuples, tried in
# order; () means replicate.
Rules = dict[str, tuple[tuple[str, ...], ...]]

TRAIN_RULES: Rules = {
    "batch": (("pod", "data"), ("data",), ()),
    "seq": ((),),
    "embed": (("data",), ()),          # FSDP shard of weight rows
    "act_embed": ((),),                # activations keep embed replicated
    "qkv": (("model",), ()),           # flattened heads*head_dim
    "heads": (("model",), ()),
    "kv_heads": (("model",), ()),
    "o_in": (("model",), ()),
    "mlp": (("model",), ()),
    "vocab": (("model",), ()),
    "lm_head": (("model",), ()),      # unembed output dim (logits vocab)
    "expert": (("model",), ()),
    "expert_mlp": ((),),
    "kv_seq": (("model",), ()),        # decode KV-cache sequence fallback
    "layers": ((),),
    "state": ((),),
    "conv": ((),),
    "cap": (("pod", "data"), ("data",), ()),  # MoE capacity slots
    "frontend": ((),),
}

SERVE_RULES: Rules = {
    **TRAIN_RULES,
    "batch": (("pod", "data"), ("data",), ()),
    "embed": ((),),                    # weights replicated over data for serve
    "kv_heads": (("model",), ()),
}

#: Expert-parallel-first variant (§Perf H-B3): the "model" axis is reserved
#: for experts; attention/shared-MLP weights drop TP (their per-layer
#: activation all-reduces vanish — they are small relative to expert FFNs
#: in fine-grained MoE), FSDP over "data" stays.
EP_RULES: Rules = {
    **TRAIN_RULES,
    "qkv": ((),),
    "heads": ((),),
    "kv_heads": ((),),
    "o_in": ((),),
    "mlp": ((),),
}

#: ZeRO-3 / pure-FSDP variant (§Perf H-A2): the "model" axis joins the batch
#: axis (TP degree 1) so per-layer TP activation all-reduces vanish; weights
#: shard their row dim over the combined (data x model) = 256-way axis and
#: are all-gathered per layer per pass.  Wins when activation-AR bytes
#: exceed weight-gather bytes (dense train at B_loc x S x d >> params/layer).
#: NOT for MoE archs: expert parallelism needs the "model" axis.
ZERO3_RULES: Rules = {
    **TRAIN_RULES,
    "batch": (("pod", "data", "model"), ("data", "model"), ("data",), ()),
    "embed": (("data", "model"), ("data",), ()),
    "qkv": ((),),
    "heads": ((),),
    "kv_heads": ((),),
    "o_in": ((),),
    "mlp": ((),),
    # vocab REPLICATED, embed-dim sharded: `take` gathers over a sharded
    # vocab dim force SPMD to replicate the whole table (measured: +6.3 GB
    # on nemotron's 256 k-vocab); with the embed dim sharded the lookup is
    # local and the (much smaller) activation gathers/psums do the work.
    # The unembed ("lm_head") stays vocab-sharded: it only feeds einsums,
    # and sharding it keeps logits AND the unembed gradient sharded
    # (replicated dW was +12.6 GB on nemotron).
    "vocab": ((),),
    "lm_head": (("data", "model"), ("model",), ()),
    "expert": ((),),
    "kv_seq": ((),),
}


_ctx = threading.local()


def _active() -> tuple[Mesh, Rules] | None:
    return getattr(_ctx, "active", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Rules):
    """Activate (mesh, rules) so model-internal ``shard_activation`` calls
    emit with_sharding_constraint; no-op outside the context."""
    prev = _active()
    _ctx.active = (mesh, rules)
    try:
        yield
    finally:
        _ctx.active = prev


def _mesh_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


#: When two dims of one tensor compete for the same mesh axis, the higher-
#: priority logical axis wins (e.g. a KV cache prefers kv_heads on "model",
#: falling back to kv_seq only when the head count is not divisible).
_PRIORITY = (
    "batch", "vocab", "lm_head", "expert", "qkv", "mlp", "kv_heads", "heads",
    "o_in", "embed", "kv_seq", "cap", "seq",
)
_PRIO = {name: i for i, name in enumerate(_PRIORITY)}


def spec_for(
    logical: Sequence[str | None], shape: Sequence[int], mesh: Mesh, rules: Rules
) -> P:
    """Resolve logical axes -> PartitionSpec under divisibility + axis-reuse
    constraints, visiting dims in logical-axis priority order."""
    used: set[str] = set()
    entries: list[Any] = [None] * len(logical)
    order = sorted(
        range(len(logical)),
        key=lambda i: _PRIO.get(logical[i], len(_PRIORITY)) if logical[i] else 1e9,
    )
    for i in order:
        name, dim = logical[i], shape[i]
        if name is None:
            continue
        for cand in rules.get(name, ((),)):
            if not cand:
                break
            if any(a in used for a in cand):
                continue
            if any(a not in mesh.shape for a in cand):
                continue
            if dim % _mesh_size(mesh, cand) != 0:
                continue
            entries[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
            break
    # Trim trailing Nones (canonical form).
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_for(
    logical: Sequence[str | None], shape: Sequence[int], mesh: Mesh, rules: Rules
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical, shape, mesh, rules))


def tree_shardings(logical_tree: Any, abstract_tree: Any, mesh: Mesh, rules: Rules) -> Any:
    """Map a pytree of logical-axis tuples + ShapeDtypeStructs to shardings."""
    return jax.tree.map(
        lambda axes, a: sharding_for(axes, a.shape, mesh, rules),
        logical_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def shard_activation(x: Array, logical: Sequence[str | None]) -> Array:
    """Constrain an activation's sharding if a rule context is active."""
    active = _active()
    if active is None:
        return x
    mesh, rules = active
    spec = spec_for(logical, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def abstract_with_sharding(abstract_tree: Any, logical_tree: Any, mesh: Mesh, rules: Rules) -> Any:
    """Attach shardings to ShapeDtypeStructs (dry-run input specs)."""
    return jax.tree.map(
        lambda a, axes: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=sharding_for(axes, a.shape, mesh, rules)
        ),
        abstract_tree,
        logical_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
