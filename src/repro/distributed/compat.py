"""Version-compatible JAX API shims.

``shard_map`` moved from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check keyword was renamed
``check_rep`` -> ``check_vma`` along the way.  Callers in this repo use the
new-style spelling (``jax.shard_map`` semantics, ``check_vma=`` keyword);
this shim maps it onto whichever implementation the installed JAX provides.
"""

from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # pragma: no cover - exercised on older jax only
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_ACCEPTS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map_impl).parameters


def shard_map(f=None, /, **kwargs):
    """``jax.shard_map`` with ``check_vma`` translated for older JAX."""
    if not _ACCEPTS_CHECK_VMA and "check_vma" in kwargs:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if f is None:
        return lambda g: _shard_map_impl(g, **kwargs)
    return _shard_map_impl(f, **kwargs)


def abstract_mesh(axis_sizes: tuple, axis_names: tuple):
    """``jax.sharding.AbstractMesh`` across its signature change.

    Newer JAX takes ``(axis_sizes, axis_names)``; 0.4.x takes one tuple of
    ``(name, size)`` pairs.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
