"""Sharded, async, elastic checkpointing with crash-safe commit.

Layout (one directory per step)::

    <dir>/step_00000420/
        manifest.json      # step, leaf index, shapes/dtypes, "complete" flag
        shard_000.npz      # leaf arrays, chunked by byte budget

Guarantees:

- **Atomic commit**: everything is written into ``<dir>/.tmp-...`` and
  renamed into place; the manifest (with ``complete: true``) is written
  *last*, so a crash mid-save can never produce a checkpoint that
  ``latest_step`` would pick up.  ``restore`` validates the manifest and
  falls back to the previous step if a directory is damaged.
- **Async**: ``CheckpointManager.save(..., blocking=False)`` snapshots to
  host memory synchronously (cheap) and writes on a background thread, so
  the train loop never waits on the filesystem.
- **Elastic re-shard**: leaves are stored unsharded (gathered); ``restore``
  ``device_put``s them onto *any* target sharding tree — a checkpoint taken
  on a (16,16) mesh restores onto (2,16,16), (4,), or a single device.  At
  1000+-node scale the same layout splits per process: each host writes the
  addressable shards of its leaves under ``shard_<process_index>_*.npz``
  (hook: ``process_index`` arg), and restore reassembles via the manifest.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"
_SHARD_BYTES = 512 * 1024 * 1024  # flush a shard file at ~512 MB


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def save_checkpoint(directory: str, step: int, state: Any, *, process_index: int = 0) -> str:
    """Write one checkpoint synchronously.  Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp-ckpt-", dir=directory)
    try:
        leaves = _leaf_paths(state)
        index: dict[str, dict] = {}
        shard_id, shard_bytes, shard_buf = 0, 0, {}

        def flush():
            nonlocal shard_id, shard_bytes, shard_buf
            if shard_buf:
                fname = f"shard_{process_index:03d}_{shard_id:03d}.npz"
                np.savez(os.path.join(tmp, fname), **shard_buf)
                shard_id += 1
                shard_bytes, shard_buf = 0, {}

        for i, (name, leaf) in enumerate(leaves):
            if leaf is None:
                index[name] = {"none": True}
                continue
            arr = np.asarray(jax.device_get(leaf))
            key = f"leaf_{i:05d}"
            fname = f"shard_{process_index:03d}_{shard_id:03d}.npz"
            index[name] = {
                "file": fname, "key": key,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
            }
            shard_buf[key] = arr
            shard_bytes += arr.nbytes
            if shard_bytes >= _SHARD_BYTES:
                flush()
        flush()
        manifest = {"step": step, "complete": True, "index": index}
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def list_steps(directory: str) -> list[int]:
    """Steps with a complete manifest, ascending."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if not name.startswith("step_"):
            continue
        try:
            with open(os.path.join(directory, name, _MANIFEST)) as f:
                m = json.load(f)
            if m.get("complete"):
                steps.append(int(m["step"]))
        except (OSError, ValueError, KeyError):
            continue  # damaged / in-flight checkpoint: skip
    return sorted(steps)


def latest_step(directory: str) -> int | None:
    """Newest checkpointed step under ``directory``, or None when empty."""
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str, step: int, target: Any, *, shardings: Any = None
) -> Any:
    """Restore ``step`` into the structure of ``target``.

    ``target`` may hold arrays or ShapeDtypeStructs (shapes are validated).
    ``shardings``: optional matching tree of NamedShardings — this is the
    elastic-reshard path; arrays are ``device_put`` onto it regardless of the
    mesh the checkpoint was written under.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise ValueError(f"checkpoint {path} is incomplete")
    index = manifest["index"]
    files: dict[str, Any] = {}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    sh_flat = None
    if shardings is not None:
        sh_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )[0]

    out = []
    for i, (kp, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(kp)
        entry = index.get(name)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {name}")
        if entry.get("none"):
            out.append(None)
            continue
        fname = entry["file"]
        if fname not in files:
            files[fname] = np.load(os.path.join(path, fname))
        arr = files[fname][entry["key"]]
        if leaf is not None and tuple(arr.shape) != tuple(np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape):
            raise ValueError(f"shape mismatch for {name}: ckpt {arr.shape} vs target {leaf.shape}")
        if sh_flat is not None:
            out.append(jax.device_put(arr, sh_flat[i]))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass
class CheckpointManager:
    """Async writer + retention policy + auto-resume."""

    directory: str
    keep: int = 3

    def __post_init__(self):
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: None if x is None else np.asarray(jax.device_get(x)), state)

        def _write():
            try:
                save_checkpoint(self.directory, step, host_state)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            if self._error:
                raise self._error
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, target: Any, *, shardings: Any = None) -> tuple[int, Any] | None:
        """(step, state) of the newest valid checkpoint, or None.

        Falls back through damaged checkpoints (crash-mid-save recovery).
        """
        for step in reversed(list_steps(self.directory)):
            try:
                return step, restore_checkpoint(
                    self.directory, step, target, shardings=shardings
                )
            except (OSError, ValueError, KeyError):
                continue
        return None

    def _gc(self) -> None:
        steps = list_steps(self.directory)
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
