"""Collectives: HLO collective-byte accounting + compressed cross-pod psum.

``collective_bytes``: the roofline's third term.  ``cost_analysis()`` does
not expose collective traffic, so we parse the compiled/lowered HLO text and
sum operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op.  Bytes are *per logical op instance*
(the tensor size that crosses links), which is the standard numerator for
``collective_bytes / (chips x link_bw)``.

``compressed_psum``: the int8 error-feedback all-reduce for the "pod" axis —
quantize the shard, psum the int8 payload (as int32 accumulators to avoid
overflow at 2+ pods), dequantize.  This is the collective counterpart of
``training.optimizer.ef_compress`` and is exercised under ``shard_map``.
"""

from __future__ import annotations

import re
from collections import defaultdict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[4,1024,512]{...}'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective kind over an HLO module text.

    Returns {kind: bytes, ..., "total": bytes}.  The *output* shape of the
    op is used (for all-gather that is the gathered tensor, for
    reduce-scatter the scattered shard, matching what actually moves per
    participant up to the algorithm factor, which the roofline's link-bw
    denominator absorbs).
    """
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # form: "%name = <shape> <op-kind>(" or "name = (<tuple shapes>) op-kind("
        m = re.match(r"^[%\w\.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # op kind appears as the called instruction name before '('
            if re.search(rf"(?:^|\s){re.escape(kind)}(?:-start|-done)?\(", rhs):
                if f"{kind}-start(" in rhs:
                    break  # async pair: count the -done (result shape only)
                prefix = rhs.split(kind)[0]
                out[kind] += _shape_bytes(prefix)
                break
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def collective_bytes_from_compiled(compiled) -> dict[str, int]:
    """Per-collective byte totals parsed from a compiled executable's HLO text."""
    return collective_bytes(compiled.as_text())


def _computation_blocks(hlo_text: str) -> dict[str, str]:
    """Split an HLO module into named computation bodies.

    Computation headers look like ``%name (args...) -> type {`` (signatures
    may contain nested parens/tuples, so only the leading ``%name (`` and the
    trailing ``{`` are matched); ``ENTRY`` marks the main computation.
    """
    blocks: dict[str, list[str]] = {}
    current = None
    for line in hlo_text.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
        if m and "->" in line:
            current = m.group(1)
            blocks[current] = []
            continue
        if line.strip().startswith("}"):
            current = None
            continue
        if current is not None:
            blocks[current].append(line)
    return {k: "\n".join(v) for k, v in blocks.items()}


def collective_bytes_structured(hlo_text: str) -> dict[str, dict[str, int]]:
    """Collective bytes split into loop-body vs top-level contributions.

    XLA's cost/byte accounting counts while-loop bodies ONCE, not x trip
    count (measured: a 10-iteration scan reports 1x the body flops).  The
    roofline therefore needs the split: callers multiply the "body" bucket
    by the known trip count (the layer-scan length — the only collective-
    bearing loops in this framework are layer scans and the microbatch
    accumulation scan; inner SSD/sLSTM scans are collective-free).

    Reachability: computations referenced (transitively) from any while op's
    ``body=`` computation are "body"; everything else is "top".
    """
    blocks = _computation_blocks(hlo_text)
    body_roots = set(re.findall(r"body=%?([\w\.\-]+)", hlo_text))
    # transitive closure of computation references from body roots
    refs = {
        name: set(re.findall(r"(?:to_apply|calls|body|condition)=%?([\w\.\-]+)", text))
        for name, text in blocks.items()
    }
    reach: set[str] = set()
    stack = [r for r in body_roots if r in blocks]
    while stack:
        n = stack.pop()
        if n in reach:
            continue
        reach.add(n)
        stack.extend(r for r in refs.get(n, ()) if r in blocks and r not in reach)

    out = {"top": defaultdict(int), "body": defaultdict(int)}
    for name, text in blocks.items():
        bucket = "body" if name in reach else "top"
        counts = collective_bytes(text)
        for k, v in counts.items():
            if k != "total":
                out[bucket][k] += v
    for bucket in out:
        out[bucket]["total"] = sum(v for k, v in out[bucket].items() if k != "total")
    return {k: dict(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# Compressed cross-pod all-reduce
# ---------------------------------------------------------------------------


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized psum over ``axis_name`` (inside shard_map/vmap).

    Payload crossing the axis is int8 + one f32 scale; accumulation happens
    in int32 so 2-256 participants cannot overflow.  Relative error is
    bounded by ~1/127 per step; pair with error feedback
    (``training.optimizer.ef_compress``) for unbiasedness over steps.
    """
    amax = jnp.max(jnp.abs(x))
    # One shared scale across the axis so dequantization is exact w.r.t. sum.
    amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return acc.astype(jnp.float32) * scale


def make_compressed_pod_mean(mesh, axis: str = "pod"):
    """shard_map'd tree-mean over the pod axis with int8 payloads."""
    n = mesh.shape[axis]

    def tree_mean(tree):
        def one(x):
            spec = P(*([None] * x.ndim))
            f = shard_map(
                lambda v: compressed_psum(v, axis) / n,
                mesh=mesh,
                in_specs=spec,
                out_specs=spec,
                check_vma=False,
            )
            return f(x)

        return jax.tree.map(one, tree)

    return tree_mean
