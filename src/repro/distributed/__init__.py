"""Distributed runtime: sharding rules, meshes, checkpointing, collectives."""
