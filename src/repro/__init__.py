"""repro: FaasMeter (Energy-First Serverless Computing) as a JAX/TPU framework.

An energy-first serving + training control plane for TPU pods:

- ``repro.core``        -- the paper's contribution: statistical power
  disaggregation, Kalman-filtered online estimation, Shapley fair attribution,
  power capping, pricing, and the energy metrology framework (validation
  metrics + marginal-energy ground truth).
- ``repro.telemetry``   -- power-source substrate (IPMI/plug/RAPL-like
  simulated sensors with matched noise/lag/quantization pathologies).
- ``repro.workload``    -- Azure-trace-style FaaS workload generation.
- ``repro.models``      -- the 10 assigned architectures (dense GQA, MoE,
  Mamba2 hybrid, xLSTM, enc-dec, VLM) as scan-over-layers JAX models.
- ``repro.training`` / ``repro.serving`` -- distributed train/serve runtimes.
- ``repro.distributed`` -- mesh/sharding rules, checkpointing, collectives.
- ``repro.kernels``     -- Pallas TPU kernels (flash attention, decode
  attention, batched disaggregation solve) + jnp reference oracles.
"""

from repro.version import __version__

__all__ = ["__version__"]
