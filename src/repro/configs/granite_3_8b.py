"""granite-3-8b — dense GQA [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155, SwiGLU, tied-free.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    mlp="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="granite-3-8b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=64,
    remat="none",
)
