"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) vocab=50304, expert d_ff=1024, no shared
experts.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    mlp="swiglu",
    num_experts=64,
    num_shared_experts=0,
    top_k=8,
    expert_d_ff=1024,
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="olmoe-1b-7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    vocab_pad_multiple=64,
    num_experts=8,
    top_k=2,
    expert_d_ff=32,
    remat="none",
)
