"""deepseek-moe-16b — fine-grained MoE [arXiv:2401.06066; hf].

28L d_model=2048 16H (GQA kv=16) vocab=102400; 64 routed experts top-6 +
2 shared experts, expert d_ff=1408; layer 0 uses a dense FFN (d_ff=
num_experts/4 * expert_d_ff = 10944 in the release; we use 16*1408).
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8 * 1408,           # dense layer-0 FFN width
    vocab_size=102400,
    mlp="swiglu",
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    first_dense=True,
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="deepseek-moe-16b-smoke",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=64,
    num_experts=8,
    num_shared_experts=2,
    top_k=2,
    expert_d_ff=32,
    remat="none",
)
