"""Assigned architecture configs (one module per arch) + shape registry."""

from repro.configs.base import ArchConfig
from repro.configs.registry import (
    ARCH_NAMES,
    all_cells,
    all_configs,
    get_config,
    get_shape,
    is_skipped,
    runnable_cells,
    shapes_for,
)
from repro.configs.shapes import SHAPES, ShapeConfig

__all__ = [
    "ArchConfig",
    "ARCH_NAMES",
    "all_cells",
    "all_configs",
    "get_config",
    "get_shape",
    "is_skipped",
    "runnable_cells",
    "shapes_for",
    "SHAPES",
    "ShapeConfig",
]
