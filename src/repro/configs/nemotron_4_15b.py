"""nemotron-4-15b — dense GQA, squared-ReLU MLP [arXiv:2402.16819; unverified].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp="sq_relu",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="nemotron-4-15b-smoke",
    num_layers=2,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    vocab_pad_multiple=64,
    remat="none",
)
