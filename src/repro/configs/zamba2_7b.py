"""zamba2-7b — hybrid: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.  A single
shared transformer block (full attention + SwiGLU FFN) is applied every
``attn_every`` Mamba2 blocks with shared weights, per the Zamba2 design.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    mlp="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    attn_every=6,
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="zamba2-7b-smoke",
    num_layers=5,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=64,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_every=2,
    remat="none",
)
