"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

24L d_model=1024 4H vocab=50304, d_ff=0 (blocks carry their own expansion).
Even layers are mLSTM (matrix memory, parallel form), odd layers sLSTM
(scalar memory, recurrent scan), 1:1 alternation.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=2,
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="xlstm-350m-smoke",
    num_layers=2,
    d_model=64,
    num_heads=2,
    head_dim=32,
    vocab_size=256,
    vocab_pad_multiple=64,
    remat="none",
)
