"""Assigned input shapes (LM-family: seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``; ``prefill_*`` lowers the prefill
step.  ``long_500k`` requires sub-quadratic attention: it runs only for the
SSM/hybrid architectures (zamba2-7b, xlstm-350m) and is a *noted skip* for
the eight pure full-attention archs (see DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

#: Families with a sub-quadratic token mixer, eligible for long_500k.
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def shapes_for(family: str) -> list[ShapeConfig]:
    """The assigned shape set for an architecture family (with noted skips)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if family in SUBQUADRATIC_FAMILIES:
        out.append(LONG_500K)
    return out


def is_skipped(family: str, shape_name: str) -> bool:
    """True when the (family, shape) cell is excluded (quadratic families at 500k)."""
    return shape_name == "long_500k" and family not in SUBQUADRATIC_FAMILIES
