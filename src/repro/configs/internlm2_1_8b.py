"""internlm2-1.8b — dense GQA [arXiv:2403.17297; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    mlp="swiglu",
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="internlm2-1.8b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=64,
    remat="none",
)
