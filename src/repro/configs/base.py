"""Architecture configuration schema.

One ``ArchConfig`` covers all six assigned families (dense GQA, MoE, Mamba2
hybrid, xLSTM, encoder-decoder, VLM); family-specific fields are zero/empty
when unused.  Every assigned architecture has a module in ``repro.configs``
exposing ``CONFIG`` (the exact published dims) and ``REDUCED`` (a same-family
smoke config small enough for a CPU forward/train step).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # --- attention / MLP options
    qkv_bias: bool = False      # qwen2.5: bias on QKV projections
    mlp: str = "swiglu"         # swiglu | sq_relu
    rope_theta: float = 1.0e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE (deepseek-moe, olmoe)
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0        # fine-grained expert width
    first_dense: bool = False   # deepseek-moe: layer 0 is a dense FFN
    capacity_factor: float = 1.25
    router_impl: str = "capacity"   # capacity | ragged (dropless)
    moe_a2a_dtype: str = "bf16"     # bf16 | int8 (quantized EP dispatch)
    kv_cache_dtype: str = "bf16"    # bf16 | int8 (quantized decode KV cache)
    ce_chunk: int = 0               # >0: sequence-chunked CE (never builds full logits)

    # --- SSM / hybrid (zamba2) and Mamba2 params
    ssm_state: int = 0          # N
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 0         # hybrid: shared attention block every k layers

    # --- xLSTM
    slstm_every: int = 0        # 0 = no sLSTM blocks; 2 = alternate m/s

    # --- encoder-decoder (seamless)
    encoder_layers: int = 0

    # --- modality frontend stubs (vlm/audio): precomputed embeddings
    frontend_tokens: int = 0    # patches/frames prepended or encoded
    frontend_dim: int = 0       # embedding dim delivered by the stub

    # --- numerics / scale
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 2048  # pad vocab so ("vocab" % model_axis == 0)
    remat: str = "full"             # none | full | dots  (activation ckpt policy)
    logit_softcap: float = 0.0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def decoder_layers(self) -> int:
        return self.num_layers

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D roofline bookkeeping)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d * (1 if self.tie_embeddings else 2)  # embed + unembed
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            if self.family == "moe":
                e_ff = self.expert_d_ff
                routed = self.num_experts * (3 * d * e_ff)
                shared = self.num_shared_experts * (3 * d * e_ff)
                router = d * self.num_experts
                mlp = routed + shared + router
            else:
                nmat = 3 if self.mlp == "swiglu" else 2
                mlp = nmat * d * self.d_ff
            per_layer = attn + mlp + 2 * d
            n += self.num_layers * per_layer
            if self.family == "encdec":
                cross = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                nmat = 3 if self.mlp == "swiglu" else 2
                n += self.encoder_layers * (attn + nmat * d * self.d_ff + 2 * d)
                n += self.num_layers * cross  # decoder cross-attention
        elif self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            mamba = d * (2 * di + 2 * self.ssm_heads * ns) + di * d + di
            n += self.num_layers * (mamba + 2 * d)
            attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            nmat = 3 if self.mlp == "swiglu" else 2
            n += attn + nmat * d * max(self.d_ff, 1)  # one shared block
        elif self.family == "ssm":  # xLSTM
            di = 2 * d
            per = d * 2 * di + di * d + 3 * di * di // max(self.num_heads, 1)
            n += self.num_layers * per
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k routed)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        e_ff = self.expert_d_ff
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        active_mlp = (self.num_shared_experts + self.top_k) * (3 * d * e_ff)
        n = self.padded_vocab * d * 2
        n += self.num_layers * (attn + active_mlp + d * self.num_experts + 2 * d)
        return n
