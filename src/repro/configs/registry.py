"""Architecture registry: ``--arch <id>`` lookup for launchers and tests."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig
from repro.configs.shapes import SHAPES, ShapeConfig, is_skipped, shapes_for

_MODULES = {
    "granite-3-8b": "repro.configs.granite_3_8b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ArchConfig:
    """Architecture config by name (``reduced`` selects the small variant)."""
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(_MODULES[name])
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(*, reduced: bool = False) -> dict[str, ArchConfig]:
    """Every registered architecture config, keyed by name."""
    return {n: get_config(n, reduced=reduced) for n in ARCH_NAMES}


def all_cells() -> list[tuple[str, str, bool]]:
    """All 40 assigned (arch, shape, skipped) cells."""
    cells = []
    for name in ARCH_NAMES:
        fam = get_config(name).family
        for sname in SHAPES:
            cells.append((name, sname, is_skipped(fam, sname)))
    return cells


def runnable_cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells not skipped on this container."""
    return [(a, s) for a, s, skip in all_cells() if not skip]


def get_shape(name: str) -> ShapeConfig:
    """Shape config by name."""
    return SHAPES[name]


__all__ = [
    "ARCH_NAMES",
    "get_config",
    "all_configs",
    "all_cells",
    "runnable_cells",
    "get_shape",
    "shapes_for",
    "is_skipped",
]
