"""seamless-m4t-large-v2 — audio encoder-decoder [arXiv:2308.11596; hf].

24L encoder + 24L decoder, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The speech frontend is a STUB per the assignment: ``input_specs()`` delivers
precomputed frame embeddings (B, frames, frontend_dim); the encoder consumes
them directly.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,           # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    mlp="swiglu",
    frontend_dim=1024,       # w2v-BERT 2.0 feature width (stubbed)
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="seamless-m4t-large-v2-smoke",
    num_layers=2,
    encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=64,
    frontend_dim=32,
    remat="none",
)
