"""internvl2-2b — VLM: InternViT frontend (stub) + InternLM2 backbone
[arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT frontend is a
STUB per the assignment: ``input_specs()`` delivers precomputed patch
embeddings (B, frontend_tokens, frontend_dim); the model owns the
projector (frontend_dim -> d_model) and the LM backbone.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    mlp="swiglu",
    frontend_tokens=256,    # 256 patch embeddings per image (448px, pixel-shuffle)
    frontend_dim=1024,      # InternViT-300M width
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="internvl2-2b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vocab_pad_multiple=64,
    frontend_tokens=8,
    frontend_dim=32,
    remat="none",
)
