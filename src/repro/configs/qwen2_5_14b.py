"""qwen2.5-14b — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    mlp="swiglu",
    qkv_bias=True,
    rope_theta=1.0e6,
)

REDUCED = dataclasses.replace(
    CONFIG,
    name="qwen2.5-14b-smoke",
    num_layers=2,
    d_model=80,
    num_heads=5,
    num_kv_heads=1,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    vocab_pad_multiple=64,
    remat="none",
)
